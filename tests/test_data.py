"""Datasets + loader."""
import numpy as np
import pytest

from repro.data.datasets import iris, kat7, kepler, ligo_glitch
from repro.data.loader import (feature_major, lm_batches, pad_feature_major,
                               pad_rows)


def test_shapes_match_paper_table3():
    Xk, yk, mk = kepler()
    assert Xk.shape == (9, 1) and yk.shape == (9,)  # 9x2 incl. target
    Xi, yi, mi = iris()
    assert Xi.shape == (150, 4) and set(np.unique(yi)) == {0, 1, 2}
    Xs, ys, ms = kat7()
    assert Xs.shape == (10_000, 9)
    Xl, yl, ml = ligo_glitch()
    assert Xl.shape == (4_000, 1_373)
    assert Xl.shape[0] * Xl.shape[1] == 5_492_000  # paper's "5.5M data points"


def test_kepler_is_keplers_law():
    X, y, _ = kepler()
    np.testing.assert_allclose(y, X[:, 0] ** 1.5, rtol=0.02)


def test_feature_major_transposition():
    X = np.arange(12, dtype=np.float32).reshape(4, 3)
    F = feature_major(X)
    assert F.shape == (3, 4)
    np.testing.assert_array_equal(F[0], X[:, 0])


def test_pad_rows():
    X = np.ones((10, 3), np.float32)
    y = np.ones((10,), np.float32)
    Xp, yp, w = pad_rows(X, y, 8)
    assert Xp.shape == (16, 3) and w.sum() == 10


@pytest.mark.parametrize("bad", [0, -1, -8, 2.5, "4", None])
def test_pad_multiple_validated(bad):
    """multiple <= 0 (or a non-int) used to fall through silently — e.g.
    `(-D) % 0` raises a bare ZeroDivisionError and negative multiples
    produced nonsense pads. Both pad doors must reject it up front."""
    X = np.ones((4, 2), np.float32)
    y = np.ones(4, np.float32)
    with pytest.raises(ValueError, match="positive integer"):
        pad_rows(X, y, bad)
    with pytest.raises(ValueError, match="positive integer"):
        pad_feature_major(np.ascontiguousarray(X.T), y, bad)


def test_pad_rows_already_multiple():
    X = np.ones((8, 2), np.float32)
    y = np.ones(8, np.float32)
    Xp, yp, w = pad_rows(X, y, 4)
    assert Xp.shape == (8, 2) and w.tolist() == [1.0] * 8
    Xf, yf, wf = pad_feature_major(np.ascontiguousarray(X.T), y, 4)
    assert Xf.shape == (2, 8) and wf.tolist() == [1.0] * 8


def test_pad_rows_empty():
    Xp, yp, w = pad_rows(np.zeros((0, 3), np.float32), np.zeros(0, np.float32), 4)
    assert Xp.shape == (0, 3) and w.shape == (0,)


def test_pad_rows_weight_passthrough():
    """Explicit sample weights survive on the real rows; padding rows are
    always 0.0 regardless."""
    X = np.ones((5, 2), np.float32)
    y = np.ones(5, np.float32)
    sw = np.array([0.5, 2.0, 1.0, 0.25, 3.0], np.float32)
    Xp, yp, w = pad_rows(X, y, 4, weight=sw)
    np.testing.assert_array_equal(w, [0.5, 2.0, 1.0, 0.25, 3.0, 0, 0, 0])
    Xf, yf, wf = pad_feature_major(np.ascontiguousarray(X.T), y, 4, weight=sw)
    np.testing.assert_array_equal(wf, w)


def test_lm_batches_deterministic():
    a = next(lm_batches(100, 2, 16, seed=3))
    b = next(lm_batches(100, 2, 16, seed=3))
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    assert a["tokens"].shape == (2, 16)
    assert (np.asarray(a["tokens"]) < 100).all()
