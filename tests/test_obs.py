"""Observability (repro.obs): the telemetry acceptance gates.

The load-bearing property is FREEDOM FROM OBSERVER EFFECTS — counters
are computed unconditionally inside the compiled evolution blocks, so
turning tracing/metrics on must not recompile anything, add host syncs,
or perturb a single bit of the trajectory. These tests pin that, plus
the trace-file schema (valid Chrome trace JSON, properly nested spans,
paired async job lanes), the elite-cache hit-rate surface on both the
session and the service, and the `repro.obs.report` summarizer.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.core import engine
from repro.data.datasets import kepler
from repro.gp import GPSession
from repro.obs import Metrics, NULL_TRACER, Tracer, counters, validate_trace
from repro.obs.metrics import BlockMonitor
from repro.service import GPService, JobSpec


def _jobs(n=3, rows=48, seed=0):
    r = np.random.RandomState(seed)
    out = []
    for i in range(n):
        X = r.randn(rows, 3).astype(np.float32)
        y = (X[:, 0] * X[:, 1]).astype(np.float32)
        out.append(JobSpec(X, y, kernel="r", generations=8, seed=i,
                           name=f"obs-{i}"))
    return out


# --- tentpole: no observer effects -------------------------------------------


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("islands", [1, 3])
@pytest.mark.parametrize("genome", ["tree", "postfix"])
def test_telemetry_on_off_bitwise_parity(backend, islands, genome, tmp_path):
    """Tracing + metrics ON yields the bitwise-identical best-fitness
    trajectory, the same generation count and the same host-sync budget
    as OFF — across backend × island layout × genome. The counter stream
    is unconditional in the compiled program, so enablement is purely a
    host-side concern."""
    X_rows, y, _ = kepler()
    kw = dict(pop_size=16, generations=10, kernel="r", backend=backend,
              genome=genome, islands=islands, migrate_every=3, migrate_k=2,
              block_size=5)
    off = GPSession(**kw)
    off.fit(X_rows, y, key=jax.random.PRNGKey(0))

    tracer = Tracer(str(tmp_path / "trace.json"))
    mreg = Metrics(str(tmp_path / "metrics.jsonl"))
    on = GPSession(tracer=tracer, metrics=mreg, **kw)
    on.fit(X_rows, y, key=jax.random.PRNGKey(0))
    mreg.close()

    np.testing.assert_array_equal(np.asarray(off.history),
                                  np.asarray(on.history))
    assert on.generation == off.generation
    assert on.stats["host_syncs"] == off.stats["host_syncs"]
    assert on.stats["blocks"] == off.stats["blocks"]
    # telemetry actually flowed on the instrumented run
    assert on.stats["tree_evals"] > 0
    with open(tracer.save()) as f:
        assert validate_trace(json.load(f)) == []


def test_telemetry_does_not_recompile_blocks():
    """Two identically-configured sessions — one silent, one fully
    instrumented — share ONE compiled evolution block: the memoized
    engine cache must not grow when the second (traced) run dispatches."""
    X_rows, y, _ = kepler()
    kw = dict(pop_size=16, generations=8, kernel="r", backend="jnp")
    s0 = GPSession(**kw)
    s0.fit(X_rows, y, key=jax.random.PRNGKey(0))
    n0 = engine.evolve_block._cache_size()
    s1 = GPSession(tracer=Tracer(), metrics=Metrics(), **kw)
    s1.fit(X_rows, y, key=jax.random.PRNGKey(0))
    assert engine.evolve_block._cache_size() == n0
    np.testing.assert_array_equal(np.asarray(s0.history),
                                  np.asarray(s1.history))


def test_counter_stream_accounts_evaluations():
    """The device counter stream's totals land in session stats: a G-
    generation run on pop P evaluates at most G*P trees (less cache
    skips), every step queried the elite cache, and the hit rate is
    consistent with the raw counters."""
    X_rows, y, _ = kepler()
    s = GPSession(pop_size=16, generations=12, kernel="r", backend="jnp")
    s.fit(X_rows, y, key=jax.random.PRNGKey(0))
    st = s.stats
    assert st["cache_queries"] == 12
    assert 0 < st["tree_evals"] <= 12 * 16
    assert st["tree_evals"] == 12 * 16 - st["cache_hits"] * 1  # elitism=1
    assert st["cache_hit_rate"] == pytest.approx(
        st["cache_hits"] / st["cache_queries"])


def test_frozen_steps_counted_not_evaluated():
    """With stop_fitness tripping at generation 1, the rest of the capped
    block self-reports as frozen compute in the counter stream."""
    X_rows, y, _ = kepler()
    s = GPSession(pop_size=16, generations=40, kernel="r", backend="jnp",
                  stop_fitness=1e9)
    s.fit(X_rows, y, key=jax.random.PRNGKey(0))
    assert s.generation == 1
    assert s.stats["frozen"] > 0
    assert s.stats["cache_queries"] == 1  # only the live step queried


# --- satellite: elite-cache hit rate on both doors ---------------------------


def test_session_cache_hit_rate_surfaces():
    """A run long enough to converge its elites reports hits > 0; with
    elite_cache=False the counters stay zeroed and the rate is 0."""
    X_rows, y, _ = kepler()
    s = GPSession(pop_size=16, generations=30, kernel="r", backend="jnp")
    s.fit(X_rows, y, key=jax.random.PRNGKey(0))
    assert s.stats["cache_hits"] > 0
    assert 0.0 < s.stats["cache_hit_rate"] <= 1.0

    s2 = GPSession(pop_size=16, generations=30, kernel="r", backend="jnp",
                   elite_cache=False)
    s2.fit(X_rows, y, key=jax.random.PRNGKey(0))
    assert s2.stats["cache_hits"] == 0 and s2.stats["cache_queries"] == 0
    assert s2.stats["cache_hit_rate"] == 0.0


def test_host_backend_cache_hit_rate_surfaces():
    """The scalar host loop feeds the same stats surface (satellite: the
    host path is not a telemetry dead zone)."""
    X_rows, y, _ = kepler()
    s = GPSession(pop_size=12, generations=12, kernel="r", backend="scalar")
    s.fit(X_rows, y)
    assert s.stats["cache_queries"] == 12
    assert s.stats["tree_evals"] > 0
    assert s.stats["blocks"] > 0 and s.stats["block_s_ema"] is not None


def test_service_cache_hit_rate_and_no_recompile(tmp_path):
    """The service aggregates slot-level cache counters; enabling
    tracer + metrics keeps the one-compiled-program guarantee."""
    tracer = Tracer(str(tmp_path / "svc.json"))
    mreg = Metrics(str(tmp_path / "svc.jsonl"))
    svc = GPService(slots=2, pop_size=32, n_features=3, data_cap=64,
                    block_size=4, tracer=tracer, metrics=mreg)
    for j in _jobs(3):
        svc.submit(j)
    svc.run()
    mreg.close()
    assert svc.stats["compiles"] == 1, svc.stats
    assert svc.stats["cache_queries"] > 0
    assert svc.stats["tree_evals"] > 0
    assert 0.0 <= svc.stats["cache_hit_rate"] <= 1.0
    # per-job async lanes all paired, spans all nested
    payload = json.load(open(tracer.save()))
    assert validate_trace(payload) == []
    phases = {e["ph"] for e in payload["traceEvents"]}
    assert {"b", "e", "B", "E"} <= phases
    names = {e["name"] for e in payload["traceEvents"]}
    assert {"admit", "dispatch", "job"} <= names


def test_service_elitism_zero_disables_cache_counters():
    svc = GPService(slots=2, pop_size=32, n_features=3, data_cap=64,
                    block_size=4, elitism=0)
    for j in _jobs(2):
        svc.submit(j)
    svc.run()
    assert svc.stats["cache_hits"] == 0 and svc.stats["cache_queries"] == 0
    assert svc.stats["cache_hit_rate"] == 0.0


# --- satellite: trace schema --------------------------------------------------


def test_trace_schema_and_nesting(tmp_path):
    """A real session run writes valid Chrome trace JSON: envelope,
    nested B/E spans (ingest, block, checkpoint), no orphan E events."""
    X_rows, y, _ = kepler()
    path = str(tmp_path / "t.json")
    tracer = Tracer(path)
    s = GPSession(pop_size=16, generations=9, kernel="r", backend="jnp",
                  block_size=3, tracer=tracer,
                  checkpoint_dir=str(tmp_path / "ck"), checkpoint_every=3)
    s.fit(X_rows, y, key=jax.random.PRNGKey(0))
    tracer.save()
    with open(path) as f:
        payload = json.load(f)
    assert validate_trace(payload) == []
    assert isinstance(payload["traceEvents"], list)
    names = {e["name"] for e in payload["traceEvents"]}
    assert {"ingest", "init", "block", "checkpoint"} <= names
    # every B has ts/pid/tid — the fields Perfetto needs to lay out lanes
    for ev in payload["traceEvents"]:
        if ev["ph"] in ("B", "E"):
            assert {"ts", "pid", "tid"} <= set(ev)


def test_validate_trace_catches_malformed():
    assert validate_trace({}) == ["traceEvents is not a list"]
    orphan = {"traceEvents": [
        {"ph": "E", "name": "x", "pid": 1, "tid": 1, "ts": 0.0}]}
    assert any("orphan E" in p for p in validate_trace(orphan))
    unclosed = {"traceEvents": [
        {"ph": "B", "name": "x", "pid": 1, "tid": 1, "ts": 0.0}]}
    assert any("unclosed B" in p for p in validate_trace(unclosed))
    dangling = {"traceEvents": [
        {"ph": "e", "name": "job", "id": "1", "pid": 1, "tid": 1, "ts": 0.0}]}
    assert any("async e without b" in p for p in validate_trace(dangling))


def test_async_lanes_idempotent():
    """Service restart replay can re-open a live lane or re-close a
    closed one; the written trace still pairs b/e exactly once."""
    t = Tracer()
    t.begin_async("job", 7)
    t.begin_async("job", 7)  # replayed admission: no-op
    t.end_async("job", 7)
    t.end_async("job", 7)  # replayed publish: no-op
    payload = {"traceEvents": t.events}
    assert validate_trace(payload) == []
    assert sum(e["ph"] == "b" for e in t.events) == 1
    assert sum(e["ph"] == "e" for e in t.events) == 1


# --- metrics registry ---------------------------------------------------------


def test_metrics_jsonl_and_snapshot(tmp_path):
    path = str(tmp_path / "m.jsonl")
    m = Metrics(path)
    m.inc("widgets", 3)
    m.gauge("depth", 5.0)
    m.observe("lat_s", 0.5)
    m.observe("lat_s", 1.5)
    m.emit("custom", hello=1)
    snap = m.snapshot()
    assert snap["counters"]["widgets"] == 3
    assert snap["gauges"]["depth"] == 5.0
    assert snap["summaries"]["lat_s"]["count"] == 2
    assert snap["summaries"]["lat_s"]["mean"] == pytest.approx(1.0)
    m.close()
    lines = [json.loads(l) for l in open(path)]
    kinds = [l["kind"] for l in lines]
    assert "custom" in kinds and kinds[-1] == "snapshot"


def test_block_monitor_routes_all_timing():
    """Satellite 6: BlockMonitor is THE block-timing path — it updates
    the metrics registry and the legacy stats dict together."""
    from repro.runtime.fault import StepMonitor

    mon = StepMonitor()
    m = Metrics()
    stats = {"blocks": 0, "block_s_ema": None, "stragglers": []}
    bm = BlockMonitor(mon, m, stats)
    for _ in range(3):
        with bm:
            pass
    assert stats["blocks"] == 3
    assert stats["block_s_ema"] == mon.ema
    assert m.counter_value("blocks") == 3
    assert m.summary("block_s")["count"] == 3


def test_counter_helpers():
    rows = np.array([[1, 1, 0, 0, 16, 40, 8], [0, 1, 1, 3, 15, 20, 9]],
                    np.int32)
    tot = counters.totals(rows)
    assert tot == {"cache_hits": 1, "cache_queries": 2, "frozen": 1,
                   "migrations": 3, "tree_evals": 31,
                   "subtree_evals_saved": 60, "unique_subtrees": 17}
    assert counters.hit_rate(tot) == pytest.approx(0.5)
    assert counters.hit_rate({"cache_hits": 0, "cache_queries": 0}) == 0.0


def test_null_tracer_is_inert():
    with NULL_TRACER.span("x"):
        pass
    with NULL_TRACER.maybe_profile(0):
        pass
    NULL_TRACER.instant("x")
    NULL_TRACER.begin_async("x", 1)
    NULL_TRACER.end_async("x", 1)
    assert NULL_TRACER.save() is None


# --- report summarizer --------------------------------------------------------


def test_report_summarizes_run_artifacts(tmp_path, capsys):
    """End to end: run with --trace/--metrics wiring, then the report
    module loads + summarizes both artifacts without error."""
    from repro.obs import report

    X_rows, y, _ = kepler()
    tpath = str(tmp_path / "t.json")
    mpath = str(tmp_path / "m.jsonl")
    tracer, mreg = Tracer(tpath), Metrics(mpath)
    s = GPSession(pop_size=16, generations=10, kernel="r", backend="jnp",
                  tracer=tracer, metrics=mreg)
    s.fit(X_rows, y, key=jax.random.PRNGKey(0))
    tracer.save()
    mreg.close()
    assert report.main([mpath, "--trace", tpath]) == 0
    out = capsys.readouterr().out
    assert "trace: valid" in out
    assert "cache hit rate" in out
    assert "block" in out


def test_absorb_block_telemetry_raw_surface():
    """The raw evolve_block() door keeps its 2-tuple no-sync contract;
    absorb_block_telemetry() is the explicit one-sync hook that folds
    the stashed device counters into stats."""
    X_rows, y, _ = kepler()
    s = GPSession(pop_size=16, generations=20, kernel="r", backend="jnp")
    s.ingest(X_rows, y)
    s.init(key=jax.random.PRNGKey(0))
    syncs0 = s.stats["host_syncs"]
    s.evolve_block(6)
    assert s.stats["host_syncs"] == syncs0  # dispatch alone never syncs
    st = s.absorb_block_telemetry()
    assert s.stats["host_syncs"] == syncs0 + 1
    assert st["cache_queries"] == 6
    assert st["tree_evals"] > 0
