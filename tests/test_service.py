"""GP-as-a-service: packed-vs-solo parity, scheduling order, cancel,
fault-injected restart, the no-recompile pin and slot invariance.

The load-bearing test is parity: a job packed into the multi-tenant
island batch must publish the SAME champion as a solo islands=1
GPSession — bitwise, not approximately. That requires feeding the solo
session the service's padded slot buffers (zero-weight padded rows,
zero feature columns): f32 reductions round differently over different
buffer shapes, so "same data" means same bytes, and the session's
`ingest(..., sample_weight=)` exists exactly for this.
"""
import numpy as np
import pytest

import jax

from repro.gp import GPSession, OperatorMix
from repro.service import (CANCELLED, DONE, PENDING, GPService, JobSpec,
                           pack_order, slot_buffers)

POP, DEPTH, FEATS, DCAP = 16, 3, 2, 32
TOURN = 6


def _dataset(seed, rows):
    r = np.random.RandomState(seed)
    X = r.randn(rows, FEATS).astype(np.float32)
    y = (X[:, 0] * X[:, 1] + 0.5 * X[:, 0]).astype(np.float32)
    return X, y


def _jobs(n, kernels=("r", "mse", "pearson"), tourn=TOURN):
    mixes = (OperatorMix(), OperatorMix(0.05, 0.05, 0.05, 0.85),
             OperatorMix(0.2, 0.2, 0.2, 0.4))
    jobs = []
    for i in range(n):
        X, y = _dataset(i, 12 + 5 * (i % 5))
        jobs.append(JobSpec(
            X, y, kernel=kernels[i % len(kernels)], mix=mixes[i % len(mixes)],
            tourn_size=tourn, stop_fitness=0.3 if i in (2, 5) else None,
            generations=4 + i % 6, seed=i, name=f"job-{i}"))
    return jobs


def _spec(seed, rows, **kw):
    kw.setdefault("tourn_size", TOURN)
    kw.setdefault("seed", seed)
    return JobSpec(*_dataset(seed, rows), **kw)


def _service(**kw):
    kw.setdefault("slots", 3)
    kw.setdefault("pop_size", POP)
    kw.setdefault("max_depth", DEPTH)
    kw.setdefault("n_features", FEATS)
    kw.setdefault("data_cap", DCAP)
    kw.setdefault("kernels", ("r",))
    kw.setdefault("tourn_draw", TOURN)
    kw.setdefault("block_size", 3)
    return GPService(**kw)


# --- the acceptance test: packed == solo, bitwise --------------------------------


def test_parity_packed_vs_solo():
    """8 heterogeneous jobs (3 kernels, 3 operator mixes, ragged rows,
    unequal budgets, two with early-stop bars) through a 3-slot service
    — so the run spans multiple admission/eviction waves — each must
    publish the same champion expression, bitwise-equal best fitness and
    generation count as its own solo islands=1 session on the same
    padded buffers. And the whole run compiles exactly one program."""
    jobs = _jobs(8)
    svc = _service(kernels=("r", "mse", "pearson"), block_size=4)
    handles = [svc.submit(j) for j in jobs]
    svc.run()

    assert all(h.status == DONE for h in handles)
    assert svc.stats["compiles"] == 1, "admission/eviction must not recompile"
    assert svc.stats["admissions"] == 8 and svc.stats["evictions"] == 8
    assert svc.heartbeats.dead_workers() == []

    for h, j in zip(handles, jobs):
        Xs, ys, ws = slot_buffers(j, FEATS, DCAP)
        sess = GPSession(pop_size=POP, max_depth=DEPTH, kernel=j.kernel,
                         mix=j.mix, tourn_size=j.tourn_size, elitism=1,
                         stop_fitness=j.stop_fitness,
                         generations=j.generations, backend="jnp")
        sess.ingest(Xs.T, ys, sample_weight=ws)
        sess.init(key=jax.random.PRNGKey(j.seed))
        sess.evolve(j.generations)
        assert h.gens_done == int(sess.generation), j.name
        assert h.best_fitness == float(sess.state.best_fitness), j.name
        assert h.best_expression == sess.best_expression(), j.name
        assert len(h.history) == h.gens_done, j.name


# --- scheduling order ------------------------------------------------------------


def test_pack_order_fifo_and_lpt():
    jobs = [JobSpec(*_dataset(i, 16), generations=g, seed=i)
            for i, g in enumerate([5, 20, 10, 20])]
    from repro.service.job import JobHandle
    handles = [JobHandle(i, j) for i, j in enumerate(jobs)]
    assert [h.job_id for h in pack_order(handles, 3, "fifo")] == [0, 1, 2]
    # lpt: largest REMAINING budget first, job_id breaks the 20/20 tie
    assert [h.job_id for h in pack_order(handles, 3, "lpt")] == [1, 3, 2]
    handles[1].gens_done = 15  # 5 remaining now
    assert [h.job_id for h in pack_order(handles, 2, "lpt")] == [3, 2]
    with pytest.raises(ValueError, match="strategy"):
        pack_order(handles, 1, "sjf")


def test_single_slot_runs_jobs_in_submit_order():
    """slots=1 + FIFO: the slot's occupant sequence must be the submit
    order, observed at every block boundary via the fault hook."""
    occupancy = []

    def spy(i):
        occupancy.extend(h.job_id for _, h in svc.batch.occupied)

    svc = _service(slots=1, fault_hook=spy)
    handles = [svc.submit(_spec(i, 16, generations=4)) for i in range(3)]
    svc.run()
    assert all(h.status == DONE for h in handles)
    # strictly non-decreasing occupant ids == FIFO, one job at a time
    assert occupancy == sorted(occupancy)
    assert set(occupancy) == {0, 1, 2}


# --- cancel ----------------------------------------------------------------------


def test_cancel_pending_and_running():
    svc = _service(slots=1, block_size=3)
    running = svc.submit(_spec(0, 16, generations=9))
    queued = svc.submit(_spec(1, 16, generations=4))

    # pending cancel: immediate, never admitted
    assert svc.cancel(queued.job_id) is True
    assert queued.status == CANCELLED and queued.gens_done == 0

    # running cancel: honoured at the next block boundary, partial results
    svc._fault_hook = lambda i: svc.cancel(running.job_id) if i == 1 else None
    svc.run()
    assert running.status == CANCELLED
    assert 0 < running.gens_done < 9
    assert running.best_expression is not None
    assert svc.cancel(running.job_id) is False  # already finished
    assert svc.idle()


# --- fault-injected restart ------------------------------------------------------


def test_restart_replays_to_identical_results(tmp_path):
    """Kill the scheduler mid-queue (injected fault), restart from the
    newest committed checkpoint: every published result must be
    identical to a fault-free run — restarts are invisible."""
    jobs = _jobs(4, kernels=("r",))

    ref = _service()
    ref_handles = [ref.submit(j) for j in jobs]
    ref.run()

    boom = {2: True}

    def fault(i):
        if boom.pop(i, False):
            raise RuntimeError("injected scheduler failure")

    svc = _service(checkpoint_dir=str(tmp_path), checkpoint_every=1,
                   fault_hook=fault)
    handles = [svc.submit(j) for j in jobs]
    svc.run()

    assert svc.stats["restarts"] == 1
    for h, r in zip(handles, ref_handles):
        assert h.status == DONE
        assert h.gens_done == r.gens_done
        assert h.best_fitness == r.best_fitness
        assert h.best_expression == r.best_expression


# --- slot invariance & elastic resume --------------------------------------------


def test_slot_invariance():
    """The same job must publish identical results from any slot, next
    to any neighbour — including with heterogeneous tournament size and
    point-mutation rate, which only slot-invariant operand encoding can
    deliver."""
    target = _spec(7, 20, generations=6, tourn_size=3, point_rate=0.5,
                   name="target")
    results = []
    for fillers in ([_spec(1, 16, generations=8)],
                    []):  # slot 1 next to a filler, then slot 0 alone
        svc = _service(slots=2)
        handles = [svc.submit(f) for f in fillers]
        t = svc.submit(target)
        svc.run()
        assert all(h.status == DONE for h in handles + [t])
        results.append((t.best_fitness, t.best_expression, t.gens_done,
                        tuple(t.history)))
    assert results[0] == results[1]


def test_adopt_resumes_at_different_slot_count():
    """A snapshot taken mid-flight on a 2-slot service, adopted by a
    3-slot service, must finish with results identical to an
    uninterrupted run — elastic resume only varies the slot count."""
    jobs = _jobs(3, kernels=("r",))
    for j in jobs:
        j.stop_fitness = None
        j.generations = 8  # > 2 blocks of 3: nothing finishes pre-snapshot

    ref = _service(slots=2)
    ref_handles = [ref.submit(j) for j in jobs]
    ref.run()

    a = _service(slots=2)
    for j in jobs:
        a.submit(j)
    a.run(max_blocks=2)  # partial: both slots mid-budget, job 2 queued
    snap = a._make_snapshot()
    assert not a.idle()

    b = _service(slots=3)
    handles = [b.submit(j) for j in jobs]  # same ids, same order
    b.adopt(snap)
    b.run()
    for h, r in zip(handles, ref_handles):
        assert h.status == DONE
        assert h.gens_done == r.gens_done
        assert h.best_fitness == r.best_fitness
        assert h.best_expression == r.best_expression


# --- submit-time validation & the job surface ------------------------------------


def test_submit_validation():
    svc = _service()
    with pytest.raises(ValueError, match="rows"):
        svc.submit(JobSpec(*_dataset(0, DCAP + 1)))
    with pytest.raises(ValueError, match="features"):
        X, y = _dataset(0, 16)
        svc.submit(JobSpec(np.concatenate([X, X], axis=1), y))
    with pytest.raises(ValueError, match="kernel"):
        svc.submit(JobSpec(*_dataset(0, 16), kernel="mse"))  # not compiled in
    with pytest.raises(ValueError, match="tourn"):
        svc.submit(JobSpec(*_dataset(0, 16), tourn_size=TOURN + 1))


def test_jobspec_validation_and_poll():
    X, y = _dataset(0, 16)
    with pytest.raises(ValueError, match="rows"):
        JobSpec(X, y[:-1])
    with pytest.raises(ValueError, match="generations"):
        JobSpec(X, y, generations=0)
    with pytest.raises(ValueError, match="unknown fitness kernel"):
        JobSpec(X, y, kernel="no-such-kernel")

    svc = _service(slots=1)
    h = svc.submit(JobSpec(X, y, generations=3, tourn_size=TOURN,
                           name="polled"))
    snap = svc.poll(h.job_id)
    assert snap["status"] == PENDING and snap["gens_done"] == 0
    assert snap["name"] == "polled" and snap["budget"] == 3
    done = svc.result(h.job_id)  # drives the loop
    assert done is h and h.status == DONE
    assert svc.poll(h.job_id)["best_expression"] == h.best_expression
