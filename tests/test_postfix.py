"""Postfix linear genomes: heap↔postfix round-trip, tree-vs-postfix fitness
parity pinned BITWISE within each eval impl, the cross-generation elite
fitness cache (hits must equal re-evaluation bit for bit), and splice-
operator invariants P1–P5 on linear genomes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import FitnessSpec, GPConfig, evolve_step, init_state
from repro.core import engine as eng
from repro.core import evolve as ev
from repro.core.islands import IslandConfig
from repro.core.trees import (TreeSpec, check_invariants, generate_population,
                              heap_to_postfix, postfix_to_heap, to_string)
from repro.kernels import ops as kops
from repro.kernels.ref import fitness_ref


def _pops(seed, pop=33, depth=5, nf=4):
    spec_t = TreeSpec(max_depth=depth, n_features=nf, n_consts=8)
    spec_p = dataclasses.replace(spec_t, genome="postfix")
    op_t, arg_t = generate_population(jax.random.PRNGKey(seed), pop, spec_t)
    op_p, arg_p = heap_to_postfix(op_t, arg_t)
    return spec_t, spec_p, (op_t, arg_t), (op_p, arg_p)


def _data(seed, nf, D):
    r = np.random.RandomState(seed)
    X = jnp.asarray(r.randn(nf, D).astype(np.float32))
    y = jnp.asarray((r.rand(D) * 3).astype(np.float32))
    return X, y


# --- representation ----------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), depth=st.integers(1, 6),
       pop=st.sampled_from([1, 9, 40]))
def test_heap_postfix_roundtrip(seed, depth, pop):
    spec_t, spec_p, (op_t, arg_t), (op_p, arg_p) = _pops(seed, pop, depth)
    check_invariants(np.asarray(op_p), spec_p)
    op_h, arg_h = postfix_to_heap(op_p, arg_p, spec_t)
    np.testing.assert_array_equal(np.asarray(op_h), np.asarray(op_t))
    np.testing.assert_array_equal(np.asarray(arg_h), np.asarray(arg_t))


def test_mixed_form_raises_value_error():
    """A heap population checked under a postfix spec (and vice versa) is
    the stale-checkpoint signature — must raise the descriptive ValueError,
    not a bare AssertionError."""
    spec_t, spec_p, (op_t, _), (op_p, _) = _pops(0, pop=16, depth=4)
    with pytest.raises(ValueError, match="genome"):
        check_invariants(np.asarray(op_t), spec_p)
    with pytest.raises(ValueError, match="genome"):
        check_invariants(np.asarray(op_p), spec_t)


def test_to_string_agrees_across_forms():
    spec_t, spec_p, (op_t, arg_t), (op_p, arg_p) = _pops(2, pop=8, depth=4)
    ct = np.asarray(spec_t.const_table())
    for i in range(8):
        s_t = to_string(np.asarray(op_t[i]), np.asarray(arg_t[i]), const_table=ct)
        s_p = to_string(np.asarray(op_p[i]), np.asarray(arg_p[i]), const_table=ct,
                        genome="postfix")
        assert s_t == s_p


# --- fitness parity: tree vs postfix, pinned bitwise -------------------------


@pytest.mark.parametrize("kernel", ["r", "mse", "pearson", "r2"])
@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_fitness_parity_tree_vs_postfix_bitwise(kernel, impl):
    """The two encodings of the same population must score bitwise-equal
    within each impl (P=100/D=777 exercises pop- and data-tile padding).
    Tiles are pinned identical for both forms — the per-genome tile
    pickers intentionally diverge by default."""
    spec_t, spec_p, (op_t, arg_t), (op_p, arg_p) = _pops(7, pop=100, depth=5)
    X, y = _data(7, 4, 777)
    fs = FitnessSpec(kernel)
    ct = spec_t.const_table()
    kw = dict(impl=impl, gather="vmem", data_tile=512, pop_tile=8)
    f_t = np.asarray(kops.fitness(op_t, arg_t, X, y, ct, spec_t, fs, **kw))
    f_p = np.asarray(kops.fitness(op_p, arg_p, X, y, ct, spec_p, fs, **kw))
    np.testing.assert_array_equal(f_t, f_p)
    # generation-1 champion parity follows, pinned explicitly
    assert int(f_t.argmin()) == int(f_p.argmin())
    assert f_t.min() == f_p.min()


def test_fitness_parity_on_reference_path():
    spec_t, spec_p, (op_t, arg_t), (op_p, arg_p) = _pops(11, pop=64, depth=5)
    X, y = _data(11, 4, 300)
    fs = FitnessSpec("r")
    ct = spec_t.const_table()
    f_t = np.asarray(fitness_ref(op_t, arg_t, X, y, ct, spec_t, fs))
    f_p = np.asarray(fitness_ref(op_p, arg_p, X, y, ct, spec_p, fs))
    np.testing.assert_array_equal(f_t, f_p)


def test_postfix_backend_agreement():
    """scalar / jnp / pallas must agree on a postfix population just as
    they do on heap trees (the existing test_gp_api parity sweep)."""
    from repro.gp import get_backend

    _, spec_p, _, (op_p, arg_p) = _pops(5, pop=24, depth=4)
    X, y = _data(5, 4, 150)
    ct = np.asarray(spec_p.const_table())
    fs = FitnessSpec("r")
    outs = {name: np.asarray(get_backend(name).fitness(
        op_p, arg_p, np.asarray(X), np.asarray(y), ct, spec_p, fs))
        for name in ("scalar", "jnp", "pallas")}
    np.testing.assert_allclose(outs["jnp"], outs["scalar"], rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(outs["jnp"], outs["pallas"], rtol=1e-5, atol=1e-4)


# --- semantic elite cache ----------------------------------------------------


def test_cached_fitness_hit_is_bitwise_reevaluation():
    """A cache hit must return exactly what re-evaluating the rows would —
    the cached value IS last generation's evaluation of identical rows."""
    spec_t, _, (op, arg), _ = _pops(3, pop=20, depth=4)
    X, y = _data(3, 4, 200)
    fs = FitnessSpec("r")
    ct = spec_t.const_table()

    def eval_rows(o, a):
        return kops.fitness(o, a, X, y, ct, spec_t, fs, impl="jnp")

    full = np.asarray(eval_rows(op, arg))
    E = 3
    state = eng.GPState(
        key=jax.random.PRNGKey(0), op=op, arg=arg,
        fitness=jnp.full((20,), jnp.inf), best_op=op[0], best_arg=arg[0],
        best_fitness=jnp.asarray(jnp.inf), generation=jnp.asarray(0),
        cache_op=op[:E], cache_arg=arg[:E], cache_fit=jnp.asarray(full[:E]))
    served = np.asarray(eng._cached_fitness(state, eval_rows))
    np.testing.assert_array_equal(served, full)
    # one perturbed cached genome -> miss -> full evaluation, same result
    miss = state._replace(cache_arg=state.cache_arg.at[0, 0].add(1))
    np.testing.assert_array_equal(np.asarray(eng._cached_fitness(miss, eval_rows)),
                                  full)


@pytest.mark.parametrize("islands", [1, 3])
@pytest.mark.parametrize("genome", ["tree", "postfix"])
def test_elite_cache_trajectory_bitwise(islands, genome):
    """elite_cache=True must not change a single bit of the evolution
    trajectory vs elite_cache=False — cache hits replace re-evaluations
    exactly, across classic and island layouts and both genome forms
    (migration rewrites last-k slots, so [:E] elites stay cache hits)."""
    spec = TreeSpec(max_depth=4, n_features=3, n_consts=8, genome=genome)
    X, y = _data(13, 3, 160)
    base = dict(pop_size=24, tree_spec=spec, fitness=FitnessSpec("r"),
                elitism=2, eval_impl="jnp",
                island=IslandConfig(islands=islands, migrate_every=2,
                                    migrate_k=2))
    s_on = init_state(GPConfig(elite_cache=True, **base), jax.random.PRNGKey(1))
    s_off = init_state(GPConfig(elite_cache=False, **base), jax.random.PRNGKey(1))
    for _ in range(6):
        s_on = evolve_step(GPConfig(elite_cache=True, **base), s_on, X, y)
        s_off = evolve_step(GPConfig(elite_cache=False, **base), s_off, X, y)
        for f in ("op", "arg", "fitness", "best_fitness", "best_op"):
            np.testing.assert_array_equal(np.asarray(getattr(s_on, f)),
                                          np.asarray(getattr(s_off, f)), err_msg=f)


def test_session_ingest_invalidates_cache():
    from repro.gp import GPSession

    X, y = _data(17, 3, 120)
    sess = GPSession(GPConfig(pop_size=16, elitism=2,
                              tree_spec=TreeSpec(max_depth=4, n_features=3,
                                                 n_consts=8),
                              fitness=FitnessSpec("r"), generations=3),
                     backend="jnp")
    sess.fit(np.asarray(X).T, np.asarray(y))
    assert np.isfinite(np.asarray(sess.state.cache_fit)).all()
    sess.ingest(np.asarray(X).T, np.asarray(y) + 1.0)  # new data: stale cache
    assert np.isinf(np.asarray(sess.state.cache_fit)).all()
    assert not np.asarray(sess.state.cache_op).any()


# --- linear-genome operators -------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_postfix_operators_preserve_invariants(seed):
    spec_t, spec_p, _, (op_p, arg_p) = _pops(seed % 1000, pop=16, depth=5)
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    op_b2, arg_b2 = generate_population(k1, 16, spec_p)
    op_x, arg_x = ev.crossover_postfix(k2, op_p, arg_p, op_b2, arg_b2, spec_p)
    check_invariants(np.asarray(op_x), spec_p)
    op_m, arg_m = ev.mutate_branch_postfix(k3, op_p, arg_p, spec_p)
    check_invariants(np.asarray(op_m), spec_p)
    op_pt, arg_pt = ev.mutate_point(k4, op_p, arg_p, spec_p, p=0.5)
    check_invariants(np.asarray(op_pt), spec_p)
    # point mutation is structure-preserving: opcodes keep their arity
    from repro.core import primitives as prim
    np.testing.assert_array_equal(prim.ARITY[np.asarray(op_pt)],
                                  prim.ARITY[np.asarray(op_p)])


def test_postfix_evolution_invariants_over_generations():
    """Full breeding dispatch (next_generation_arrays under evolve_step)
    must keep every postfix generation P1–P5-valid."""
    spec = TreeSpec(max_depth=5, n_features=3, n_consts=8, genome="postfix")
    cfg = GPConfig(pop_size=32, tree_spec=spec, fitness=FitnessSpec("r"),
                   elitism=1, eval_impl="jnp")
    X, y = _data(19, 3, 128)
    state = init_state(cfg, jax.random.PRNGKey(4))
    for _ in range(5):
        state = evolve_step(cfg, state, X, y)
        check_invariants(np.asarray(state.op), spec)
    assert float(state.best_fitness) < float("inf")


# --- checkpoint format guard -------------------------------------------------


def test_checkpoint_leaf_count_mismatch_is_descriptive(tmp_path):
    """Restoring a pre-elite-cache checkpoint into the new GPState layout
    must fail with the migration hint, not an opaque unflatten error."""
    from repro.ckpt import checkpoint as ck

    old = {"op": np.zeros((4, 15), np.int32), "fit": np.zeros((4,), np.float32)}
    ck.save(old, str(tmp_path), 0)
    new_layout = {"op": old["op"], "fit": old["fit"],
                  "cache_fit": np.zeros((2,), np.float32)}
    with pytest.raises(ValueError, match="state\n?\\s*format changed|format changed"):
        ck.restore(str(tmp_path), 0, like=new_layout)
    leaves, manifest = ck.restore(str(tmp_path), 0, like=None)
    assert len(leaves) == 2
