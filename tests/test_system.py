"""End-to-end behaviour tests for the paper's system.

The paper's claim structure: (1) the vectorized engine produces the same
evolution semantics as the scalar baseline (same fitness function, same
operators); (2) it is dramatically faster (benchmarks/); (3) it solves the
reference problems. These tests pin (1) and (3); (2) is measured by
benchmarks/run.py.
"""
import jax
import numpy as np

from repro.core import GPConfig, TreeSpec, FitnessSpec, init_state, evolve_step, run
from repro.core.scalar_eval import fitness_scalar
from repro.data.datasets import iris, kat7, kepler
from repro.data.loader import feature_major


def test_vectorized_and_scalar_agree_on_evolved_population():
    """Evolve with the vectorized engine, then re-score the final population
    with the paper-baseline scalar interpreter — identical fitness."""
    X_rows, y, meta = iris()
    spec = TreeSpec(max_depth=4, n_features=4, n_consts=8)
    cfg = GPConfig(pop_size=30, tree_spec=spec,
                   fitness=FitnessSpec("c", n_classes=3), generations=5)
    state = run(cfg, feature_major(X_rows), y, key=jax.random.PRNGKey(1))
    scalar = fitness_scalar(np.asarray(state.op), np.asarray(state.arg), X_rows, y,
                            np.asarray(spec.const_table()), kernel="c", n_classes=3)
    from repro.kernels.ref import fitness_ref
    import jax.numpy as jnp
    vector = np.asarray(fitness_ref(state.op, state.arg,
                                    jnp.asarray(feature_major(X_rows)), jnp.asarray(y),
                                    spec.const_table(), spec, cfg.fitness))
    np.testing.assert_allclose(vector, scalar, rtol=1e-4, atol=1e-3)


def test_kat7_end_to_end_improves():
    """The paper's flagship dataset (shape-faithful synthetic): population
    fitness must improve over generations on 90k data points."""
    X_rows, y, meta = kat7(rows=2000)  # reduced rows for CI speed
    cfg = GPConfig(pop_size=60, tree_spec=TreeSpec(max_depth=5, n_features=9,
                                                   n_consts=8),
                   fitness=FitnessSpec("c", n_classes=2), generations=8)
    X = feature_major(X_rows)
    state = init_state(cfg, jax.random.PRNGKey(0))
    first_best = None
    for g in range(cfg.generations):
        state = evolve_step(cfg, state, X, y)
        if g == 0:
            first_best = float(state.best_fitness)
    assert float(state.best_fitness) <= first_best
    acc = -float(state.best_fitness) / len(y)
    assert acc > 0.55  # beats coin flip on the synthetic RFI rule


def test_generation_step_is_single_compilation():
    """The core TPU adaptation claim: evolve_step must not retrace across
    generations (trees are data, not code)."""
    X_rows, y, _ = kepler()
    spec = TreeSpec(max_depth=4, n_features=1, n_consts=8)
    cfg = GPConfig(pop_size=20, tree_spec=spec, fitness=FitnessSpec("r"),
                   generations=3)
    X = jax.numpy.asarray(feature_major(X_rows))
    yj = jax.numpy.asarray(y)
    state = init_state(cfg, jax.random.PRNGKey(0))
    from repro.core.engine import evolve_step as step
    state = step(cfg, state, X, yj)
    n0 = step._cache_size()
    for _ in range(4):
        state = step(cfg, state, X, yj)
    assert step._cache_size() == n0
