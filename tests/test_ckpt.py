"""Checkpointing: roundtrip, corruption detection, retention, async,
elastic resharding across different meshes (subprocess)."""
import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager, latest_step, restore, save


def _tree(seed=0):
    r = np.random.RandomState(seed)
    return {"a": jnp.asarray(r.randn(4, 8).astype(np.float32)),
            "nested": {"b": jnp.asarray(r.randint(0, 9, (3,)).astype(np.int32)),
                       "c": jnp.asarray(r.randn(2).astype(np.float32))}}


def test_roundtrip():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        save(t, d, 3)
        back = restore(d, 3, like=t)
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert latest_step(d) == 3


def test_corruption_detected():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        p = save(t, d, 1)
        victim = os.path.join(p, "000000.npy")
        arr = np.load(victim)
        arr.flat[0] += 1.0
        np.save(victim, arr)
        with pytest.raises(IOError, match="corruption"):
            restore(d, 1, like=t)


def test_torn_write_not_visible():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        save(t, d, 5)
        os.makedirs(os.path.join(d, "step_00000009.tmp"))  # crashed save
        assert latest_step(d) == 5


def test_manager_async_retention():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d, keep=2, every=1)
        for s in range(1, 6):
            m.maybe_save(t, s)
        m.wait()
        steps = sorted(int(x.split("_")[1]) for x in os.listdir(d))
        assert steps == [4, 5]
        (restored, s0) = m.restore_latest(like=t)
        assert s0 == 5 and restored is not None


_ELASTIC = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_reduced
    from repro.launch.mesh import make_host_mesh, batch_axes
    from repro import compat
    from repro.launch import sharding as SH
    from repro.models import model as Md
    from repro.models.transformer import ShardingPolicy
    from repro.optim.adamw import for_config
    from repro.ckpt.checkpoint import save, restore
    from repro.ckpt.elastic import reshard_state

    cfg = get_reduced("gemma-2b")
    mesh_a = make_host_mesh(data=2, model=4)
    cfg_a = cfg.with_policy(ShardingPolicy(batch=batch_axes(mesh_a), tp_size=4))
    opt = for_config(cfg_a)
    params = Md.init_params(cfg_a, jax.random.PRNGKey(0))
    state = {"params": params, "opt": opt.init(params), "step": jnp.zeros((), jnp.int32)}
    with tempfile.TemporaryDirectory() as d:
        save(state, d, 1)
        host = restore(d, 1, like=state)
        # restart on a DIFFERENT mesh shape (elastic scaling)
        mesh_b = make_host_mesh(data=4, model=2)
        cfg_b = cfg.with_policy(ShardingPolicy(batch=batch_axes(mesh_b), tp_size=2))
        state_b = reshard_state(host, cfg_b, mesh_b)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state_b)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # and it can actually take a train step on the new mesh
        shapes = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state_b)
        specs = SH.train_state_specs(cfg_b, shapes, mesh_b)
        step = jax.jit(Md.make_train_step(cfg_b, opt, param_specs=specs["params"]))
        toks = jnp.zeros((4, 16), jnp.int32)
        batch = {"tokens": toks, "labels": toks, "mask": jnp.ones((4,16), jnp.float32)}
        with compat.set_mesh(mesh_b):
            state_b2, m = step(state_b, batch)
        assert np.isfinite(float(m["loss"]))
    print("ELASTIC_OK")
""")


@pytest.mark.tier2
def test_elastic_reshard_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _ELASTIC], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "ELASTIC_OK" in r.stdout


_ELASTIC_GP = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import compat
    from repro.core import engine
    from repro.ckpt.checkpoint import save, restore
    from repro.ckpt.elastic import reshard_gp_state
    from repro.gp import GPSession, MeshTopology
    from repro.launch.mesh import make_host_mesh

    rng = np.random.RandomState(3)
    X_rows = np.abs(rng.randn(128, 2)).astype(np.float32) + 0.5
    y = (X_rows[:, 0] ** 2 / X_rows[:, 1]).astype(np.float32)

    # islands=4 run on a (data=2, model=2, pod=2) mesh, a few generations in
    s = GPSession(pop_size=16, generations=4, kernel="r", islands=4,
                  migrate_every=100,  # no mid-run migration: pure evolution
                  topology=MeshTopology(data=2, model=2, pod=2))
    s.fit(X_rows, y)
    cfg = s._cfg
    host = jax.tree.map(np.asarray, jax.device_get(s.state))

    with tempfile.TemporaryDirectory() as d:
        save(host, d, 1)
        back = restore(d, 1, like=host)
        # restart on a DIFFERENT pod/model split (elastic GP scaling):
        # 4 islands over pod=4, each population unsharded (model=1)
        mesh_b = make_host_mesh(data=2, model=1, pod=4)
        state_b = reshard_gp_state(back, cfg, mesh_b, pod_axis="pod")
        for a, b in zip(jax.tree.leaves(host), jax.tree.leaves(state_b)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # the champion survived re-placement bit-for-bit
        assert float(jnp.min(state_b.best_fitness)) == float(np.min(host.best_fitness))
        # and the resharded state can actually take a step on the new mesh
        step, specs = engine.sharded_evolve_step(cfg, mesh_b, pod_axis="pod")
        from repro.data.loader import pad_feature_major
        X_fm, yy, w = pad_feature_major(X_rows.T.copy(), y, 2)
        Xd = jax.device_put(jnp.asarray(X_fm), NamedSharding(mesh_b, P(None, "data")))
        yd = jax.device_put(jnp.asarray(yy), NamedSharding(mesh_b, P("data")))
        wd = jax.device_put(jnp.asarray(w), NamedSharding(mesh_b, P("data")))
        with compat.set_mesh(mesh_b):
            state_b2 = jax.jit(step)(state_b, Xd, yd, wd)
        assert int(jnp.max(state_b2.generation)) == int(np.max(host.generation)) + 1
        assert float(jnp.min(state_b2.best_fitness)) <= float(np.min(host.best_fitness))
    print("ELASTIC_GP_OK")
""")


@pytest.mark.tier2
def test_elastic_gp_reshard_subprocess():
    """A GPState from an islands=4 run saved on a (2,2,2) mesh restores
    and resharded onto a (2,1,4) mesh bit-identically — champion
    included — and the new mesh can evolve it further."""
    env = dict(os.environ, PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _ELASTIC_GP], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "ELASTIC_GP_OK" in r.stdout
