"""First-class island-model evolution: migration semantics (ring arrival
order, torus alternation, broadcast-best, migrate_every phase under
ragged block boundaries, no migration from frozen generations),
islands=1 bitwise-legacy, heterogeneous per-island search, island-batched
checkpoint round-trip, the scalar-backend island loop, and the
pods × in-device-islands mesh path (subprocess)."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GPConfig, IslandConfig, OperatorMix, TreeSpec, engine
from repro.core import fitness as fit
from repro.core import islands as isl
from repro.core.trees import generate_population
from repro.data.datasets import kepler
from repro.data.loader import feature_major
from repro.gp import GPSession


def _tagged_elites(I, k, N, base=100):
    """int32[I, k, N] elites whose values identify their source island."""
    e = np.zeros((I, k, N), np.int32)
    for i in range(I):
        e[i] = base * (i + 1)
    return jnp.asarray(e), jnp.asarray(e + 7)


def _island_cfg(pop=12, islands=4, migrate_every=2, migrate_k=2, depth=4, **kw):
    return GPConfig(
        pop_size=pop, tree_spec=TreeSpec(max_depth=depth, n_features=1, n_consts=8),
        island=IslandConfig(islands=islands, migrate_every=migrate_every,
                            migrate_k=migrate_k, **kw))


# --- migration routing (unit) ------------------------------------------------


def test_migrate_local_ring_arrival_order():
    """Ring: island i's last-k offspring slots receive island (i-1)'s
    elites on a due generation, and nothing moves off-cycle."""
    I, P, N, k = 4, 6, 5, 2
    icfg = IslandConfig(islands=I, migrate_every=3, migrate_k=k)
    e_op, e_arg = _tagged_elites(I, k, N)
    new_op = jnp.zeros((I, P, N), jnp.int32)
    new_arg = jnp.zeros((I, P, N), jnp.int32)
    fit_best = jnp.zeros((I,), jnp.float32)

    # generation 2 → 2 % 3 == 2 == migrate_every - 1: due
    out_op, out_arg = isl.migrate_local(icfg, new_op, new_arg, e_op, e_arg,
                                        jnp.asarray(2), fit_best)
    for i in range(I):
        src = (i - 1) % I
        np.testing.assert_array_equal(np.asarray(out_op)[i, -k:],
                                      np.asarray(e_op)[src],
                                      err_msg=f"island {i} should hold "
                                              f"island {src}'s elites")
        np.testing.assert_array_equal(np.asarray(out_arg)[i, -k:],
                                      np.asarray(e_arg)[src])
        assert (np.asarray(out_op)[i, :-k] == 0).all()  # only last-k slots

    # generation 1 → off-cycle: unchanged
    out_op, _ = isl.migrate_local(icfg, new_op, new_arg, e_op, e_arg,
                                  jnp.asarray(1), fit_best)
    assert (np.asarray(out_op) == 0).all()


def test_migrate_local_torus_alternates_directions():
    """Torus on a 2x2 grid: even migration events shift east (within
    grid rows), odd events shift south (across rows)."""
    I, P, N, k = 4, 4, 3, 1
    icfg = IslandConfig(islands=I, migrate_every=1, migrate_k=k,
                        topology="torus")
    e_op, e_arg = _tagged_elites(I, k, N)
    zeros = jnp.zeros((I, P, N), jnp.int32)
    fb = jnp.zeros((I,), jnp.float32)

    # grid index: island i = (row r = i // 2, col c = i % 2)
    # event 0 (generation 0): east — (r, c) receives (r, c-1)
    out_e, _ = isl.migrate_local(icfg, zeros, zeros, e_op, e_arg,
                                 jnp.asarray(0), fb)
    # event 1 (generation 1): south — (r, c) receives (r-1, c)
    out_s, _ = isl.migrate_local(icfg, zeros, zeros, e_op, e_arg,
                                 jnp.asarray(1), fb)
    for i in range(I):
        r, c = divmod(i, 2)
        east_src = r * 2 + (c - 1) % 2
        south_src = ((r - 1) % 2) * 2 + c
        np.testing.assert_array_equal(np.asarray(out_e)[i, -k:],
                                      np.asarray(e_op)[east_src])
        np.testing.assert_array_equal(np.asarray(out_s)[i, -k:],
                                      np.asarray(e_op)[south_src])


def test_migrate_local_broadcast_best():
    """broadcast-best: every island receives the champion island's elites
    (champion = argmin of the per-island best fitness)."""
    I, P, N, k = 3, 4, 3, 2
    icfg = IslandConfig(islands=I, migrate_every=1, migrate_k=k,
                        topology="broadcast-best")
    e_op, e_arg = _tagged_elites(I, k, N)
    zeros = jnp.zeros((I, P, N), jnp.int32)
    fb = jnp.asarray([3.0, 1.0, 2.0])  # island 1 is champion
    out_op, _ = isl.migrate_local(icfg, zeros, zeros, e_op, e_arg,
                                  jnp.asarray(0), fb)
    for i in range(I):
        np.testing.assert_array_equal(np.asarray(out_op)[i, -k:],
                                      np.asarray(e_op)[1])


def test_torus_grid_factorization():
    assert isl.torus_grid(4) == (2, 2)
    assert isl.torus_grid(12) == (3, 4)
    assert isl.torus_grid(7) == (1, 7)  # prime → degenerates to a ring


# --- layout & engine ---------------------------------------------------------


def test_islands_one_is_bitwise_legacy():
    """islands=1 keeps the legacy un-batched state and the exact same
    trajectory as a config that never mentions islands."""
    X_rows, y, _ = kepler()
    s0 = GPSession(pop_size=16, generations=4, kernel="r", backend="jnp")
    s0.fit(X_rows, y, key=jax.random.PRNGKey(0))
    s1 = GPSession(pop_size=16, generations=4, kernel="r", backend="jnp",
                   islands=1)
    s1.fit(X_rows, y, key=jax.random.PRNGKey(0))
    assert s1.state.op.ndim == 2 and s1.island_history == []
    for name, a, b in zip(s0.state._fields, jax.tree.leaves(s0.state),
                          jax.tree.leaves(s1.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"GPState.{name} diverged")


def test_island_block_bitwise_identical_to_stepwise():
    """K scanned island generations == K dispatched island steps, bit for
    bit — migrations land on the same absolute generations either way."""
    X_rows, y, _ = kepler()
    cfg = _island_cfg(pop=12, islands=4, migrate_every=2, migrate_k=2)
    X, yj = jnp.asarray(feature_major(X_rows)), jnp.asarray(y)
    K = 5
    s_step = engine.init_state(cfg, jax.random.PRNGKey(0))
    for _ in range(K):
        s_step = engine.evolve_step(cfg, s_step, X, yj)
    s_blk, hist, _ = engine.evolve_block(
        cfg, engine.init_state(cfg, jax.random.PRNGKey(0)), X, yj, None,
        n_steps=K)
    for name, a, b in zip(s_step._fields, jax.tree.leaves(s_step),
                          jax.tree.leaves(s_blk)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"GPState.{name} diverged")
    assert hist.shape == (K, 4)  # per-island best-fitness streams
    np.testing.assert_array_equal(np.asarray(hist)[-1],
                                  np.asarray(s_step.best_fitness))


def test_migration_phase_stable_under_ragged_blocks():
    """migrate_every phase is anchored to the absolute generation
    counter: ragged block boundaries (callback period 3 against
    migrate_every 2, final partial block) reproduce the monolithic run
    bit for bit."""
    X_rows, y, _ = kepler()
    kw = dict(pop_size=12, generations=7, kernel="r", backend="jnp",
              islands=3, migrate_every=2, migrate_k=2)
    s_ragged = GPSession(callback=lambda g, st: None, callback_every=3, **kw)
    s_ragged.fit(X_rows, y, key=jax.random.PRNGKey(1))  # blocks 3, 3, 1
    s_mono = GPSession(**kw)
    s_mono.fit(X_rows, y, key=jax.random.PRNGKey(1))  # one block of 7
    assert s_ragged.stats["blocks"] == 3 and s_mono.stats["blocks"] == 1
    for name, a, b in zip(s_mono.state._fields, jax.tree.leaves(s_mono.state),
                          jax.tree.leaves(s_ragged.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"GPState.{name} diverged")
    np.testing.assert_array_equal(np.asarray(s_mono.island_history),
                                  np.asarray(s_ragged.island_history))


def test_frozen_generations_do_not_migrate():
    """Early-stop freeze discards frozen generations wholesale —
    including their migrations. With migrate_every=1 (a migration EVERY
    generation) and a stop threshold reached at generation 1, a 8-step
    block must leave the state exactly where step 1 left it."""
    X_rows, y, _ = kepler()
    cfg = dataclasses.replace(_island_cfg(pop=12, islands=3, migrate_every=1,
                                          migrate_k=2), stop_fitness=1e9)
    X, yj = jnp.asarray(feature_major(X_rows)), jnp.asarray(y)
    one = engine.evolve_step(cfg, engine.init_state(cfg, jax.random.PRNGKey(0)),
                             X, yj)
    blk, hist, _ = engine.evolve_block(
        cfg, engine.init_state(cfg, jax.random.PRNGKey(0)), X, yj, None,
        n_steps=8)
    assert int(blk.generation) == 1
    for name, a, b in zip(one._fields, jax.tree.leaves(one),
                          jax.tree.leaves(blk)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"GPState.{name} diverged")
    # history rows after the freeze all repeat generation 1's snapshot
    assert np.all(np.asarray(hist) == np.asarray(hist)[0])


def test_heterogeneous_island_session():
    """Per-island operator mixes / tournament sizes / point rates drive
    one compiled program; per-island streams and champions surface."""
    X_rows, y, _ = kepler()
    s = GPSession(
        pop_size=12, generations=5, kernel="r", backend="jnp", islands=3,
        migrate_every=2, migrate_k=1,
        island_mixes=(OperatorMix(), OperatorMix(0.05, 0.05, 0.05, 0.85),
                      OperatorMix(0.1, 0.3, 0.3, 0.3)),
        island_tourn_sizes=(4, 10, 7), island_point_rates=(0.1, 0.25, 0.5))
    s.fit(X_rows, y, key=jax.random.PRNGKey(0))
    assert s.islands == 3
    assert s.state.op.shape[0] == 3
    assert len(s.history) == 5 and len(s.island_history) == 5
    assert s.island_history[0].shape == (3,)
    assert s.island_best_fitness.shape == (3,)
    assert len(s.island_expressions()) == 3
    assert s.best_fitness == pytest.approx(float(s.island_best_fitness.min()))
    # per-generation mins agree between the two histories
    np.testing.assert_allclose(np.asarray(s.history),
                               np.asarray(s.island_history).min(axis=1))
    # champion decode/predict pick the best island
    assert len(s.best_expression()) > 0
    assert s.predict(X_rows[:4]).shape == (4,)


def test_island_config_validation():
    with pytest.raises(ValueError, match="topology"):
        IslandConfig(topology="hypercube")
    with pytest.raises(ValueError, match="mixes"):
        IslandConfig(islands=3, mixes=(OperatorMix(),))
    with pytest.raises(ValueError, match="migrate_every"):
        IslandConfig(migrate_every=0)  # % 0 in jit is silent garbage
    with pytest.raises(ValueError, match="islands"):
        IslandConfig(islands=0)
    with pytest.raises(ValueError, match="migrate_k"):
        IslandConfig(migrate_k=-1)
    with pytest.raises(ValueError, match="migrate_k"):
        engine.init_state(_island_cfg(pop=4, islands=2, migrate_k=8),
                          jax.random.PRNGKey(0))


def test_legacy_migrate_aliases_fold_into_island_config():
    """GPConfig(migrate_every=3) — the pre-island flat surface — lands on
    IslandConfig and the legacy fields mirror it; an explicit
    IslandConfig value always beats the alias, so replacing the island
    on a config that once used the alias can't resurrect the old value."""
    cfg = GPConfig(migrate_every=3, migrate_k=2)
    assert cfg.island.migrate_every == 3 and cfg.island.migrate_k == 2
    assert cfg.migrate_every == 3 and cfg.migrate_k == 2
    cfg2 = GPConfig(island=IslandConfig(islands=2, migrate_every=7))
    assert cfg2.migrate_every == 7
    # the stale mirror (3) must not clobber the explicitly requested 20
    cfg3 = dataclasses.replace(cfg, island=IslandConfig(islands=4,
                                                        migrate_every=20))
    assert cfg3.island.migrate_every == 20 and cfg3.migrate_every == 20


def test_island_state_checkpoint_roundtrip(tmp_path):
    """The island-batched GPState pytree round-trips through the
    checkpoint layer — and a session resumes from it."""
    from repro.ckpt import checkpoint as ck

    X_rows, y, _ = kepler()
    s = GPSession(pop_size=12, generations=4, kernel="r", backend="jnp",
                  islands=3, migrate_every=2,
                  checkpoint_dir=str(tmp_path), checkpoint_every=2)
    s.fit(X_rows, y, key=jax.random.PRNGKey(0))
    s._manager.wait()
    restored, step = s._manager.restore_latest(like=jax.device_get(s.state))
    assert step == 4
    for name, a, b in zip(s.state._fields, jax.tree.leaves(s.state),
                          jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"GPState.{name} diverged")
    # a fresh session restores and continues from generation 4
    s2 = GPSession(pop_size=12, generations=4, kernel="r", backend="jnp",
                   islands=3, migrate_every=2,
                   checkpoint_dir=str(tmp_path), checkpoint_every=2)
    s2.ingest(X_rows, y)
    s2.init(key=jax.random.PRNGKey(9))
    assert s2._gen_host == 4
    assert s2.state.op.shape == (3, 12, 63)
    del ck  # imported to assert the module stays importable standalone


def test_scalar_backend_runs_islands():
    """The paper's 1-CPU_SP baseline runs the same island semantics on
    the host (per-island breeding + in-device migration lowering)."""
    X_rows, y, _ = kepler()
    s = GPSession(pop_size=10, generations=3, kernel="r", backend="scalar",
                  islands=3, migrate_every=2, migrate_k=1)
    s.fit(X_rows[:40], y[:40])
    assert s.state.op.shape == (3, 10, 63)
    assert len(s.island_history) == 3 and s.island_history[0].shape == (3,)
    assert np.isfinite(s.best_fitness)


# --- centered moments: hoisting + Chan combine --------------------------------


def test_y_moment_hoisting_roundtrip():
    """The tree-independent columns marked by y_moment_idx really are
    tree-independent, equal y_moments(y, w), and scatter_tree_y
    reassembles the full moment vector exactly."""
    rng = np.random.RandomState(0)
    preds = jnp.asarray(rng.randn(5, 64).astype(np.float32))
    y = jnp.asarray(rng.randn(64).astype(np.float32))
    w = jnp.ones(64)
    for name in ("pearson", "r2"):
        k = fit.get_kernel(name)
        spec = fit.FitnessSpec(name)
        m = k.moments(preds, y, w, spec)  # [P, M]
        y_cols = np.asarray(m)[:, list(k.y_moment_idx)]
        np.testing.assert_array_equal(y_cols, np.broadcast_to(y_cols[0],
                                                              y_cols.shape))
        np.testing.assert_allclose(y_cols[0],
                                   np.asarray(k.y_moments(y, w, spec)),
                                   rtol=1e-6)
        rebuilt = fit.scatter_tree_y(
            k, m[:, jnp.asarray(k.tree_moment_idx)], jnp.asarray(y_cols[0]))
        np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(m))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_centered_moments_survive_large_mean_targets(backend):
    """The ROADMAP cancellation caveat is closed: on a |mean| >> std
    target (1e4 ± 1 — raw moments lost ALL variance resolution here),
    the tiled moment paths now match the exact centered single pass."""
    from repro.gp import get_backend

    spec = TreeSpec(max_depth=4, n_features=2, n_consts=8)
    op, arg = generate_population(jax.random.PRNGKey(5), 16, spec)
    rng = np.random.RandomState(0)
    X = rng.randn(2, 512).astype(np.float32)
    y = (1e4 + rng.randn(512)).astype(np.float32)
    consts = np.asarray(spec.const_table())
    be = get_backend(backend)
    for kernel in ("pearson", "r2"):
        fs = fit.FitnessSpec(kernel)
        kern = fit.get_kernel(kernel)
        preds = be.evaluate(op, arg, jnp.asarray(X), jnp.asarray(consts), spec)
        exact = np.asarray(fit.fitness_from_preds(
            jnp.asarray(preds), jnp.asarray(y), fs))
        # small tiles force many cross-tile merges (Pallas grid / scan).
        # atol 2e-3: pearson's noise-floor guard may round a genuinely
        # noise-level correlation (r² ~ 1e-3 on this target) down to 0 —
        # the documented resolution limit, nothing like the old
        # catastrophic mode where EVERY tree collapsed to fitness 1.0
        tiled = np.asarray(kern.reduce_moments(
            be.moments(op, arg, jnp.asarray(X), jnp.asarray(y), consts, spec,
                       fs, data_tile=128), fs))
        np.testing.assert_allclose(tiled, exact, rtol=2e-3, atol=2e-3,
                                   err_msg=f"{backend}/{kernel}")


def test_combine_moments_fold_matches_exact():
    """Simulated 4-shard merge via fold_moment_partials == the exact
    centered single pass (the test_gp_api degenerate-trees test covers
    the guard; this one pins plain accuracy)."""
    rng = np.random.RandomState(1)
    preds = jnp.asarray(rng.randn(6, 256).astype(np.float32))
    y = jnp.asarray((5 + rng.randn(256)).astype(np.float32))
    w = jnp.ones(256)
    for name in ("pearson", "r2"):
        k = fit.get_kernel(name)
        spec = fit.FitnessSpec(name)
        exact = np.asarray(k.partial_fitness(preds, y, w, spec))
        parts = [k.moments(preds[:, i * 64:(i + 1) * 64],
                           y[i * 64:(i + 1) * 64], w[i * 64:(i + 1) * 64],
                           spec) for i in range(4)]
        merged = np.asarray(k.reduce_moments(
            fit.fold_moment_partials(k, parts, spec), spec))
        np.testing.assert_allclose(merged, exact, rtol=1e-4, atol=1e-5,
                                   err_msg=name)
        # zero partials are merge identities (scan-accumulator contract)
        zed = fit.fold_moment_partials(
            k, [jnp.zeros_like(parts[0]), parts[0]], spec)
        np.testing.assert_allclose(np.asarray(zed), np.asarray(parts[0]),
                                   rtol=1e-6, err_msg=f"{name} identity")


# --- mesh: pods × in-device islands (subprocess) ------------------------------

_SUBPROCESS_ISLAND_MESH = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.gp import GPSession, MeshTopology

    rng = np.random.RandomState(1)
    X_rows = np.abs(rng.randn(128, 2)).astype(np.float32) + 0.5
    y = (X_rows[:, 0] ** 2 / X_rows[:, 1]).astype(np.float32)

    # ACCEPTANCE: islands=4 on an 8-device mesh — 2 pods x 2 in-device
    # islands, each island's population sharded over model — from the
    # same GPSession.fit() call as the single-device run
    s = GPSession(pop_size=16, generations=8, kernel="r", islands=4,
                  migrate_every=3, migrate_k=2,
                  topology=MeshTopology(data=2, model=2, pod=2))
    s.fit(X_rows, y)
    assert s.state.op.shape == (4, 16, 63), s.state.op.shape
    assert s.generation == 8
    assert len(s.island_history) == 8
    assert s.island_history[0].shape == (4,)
    assert np.isfinite(s.best_fitness)
    assert len(s.best_expression()) > 0
    assert s.stats["host_syncs"] == 1, s.stats

    # in-device islands on a pod-less mesh: island axis replicated,
    # populations sharded over model, data sharded (ragged rows pad)
    s2 = GPSession(pop_size=16, generations=4, kernel="r", islands=4,
                   migrate_every=2, topology=MeshTopology(data=2, model=2))
    s2.fit(X_rows[:101], y[:101])
    assert s2.state.op.shape == (4, 16, 63)
    assert s2.n_rows == 101 and np.isfinite(s2.best_fitness)

    # torus + broadcast-best route on the pod mesh too
    for topo in ("torus", "broadcast-best"):
        st = GPSession(pop_size=8, generations=4, kernel="r", islands=4,
                       migrate_every=2, migrate_k=1, island_topology=topo,
                       topology=MeshTopology(data=2, model=2, pod=2))
        st.fit(X_rows, y)
        assert np.isfinite(st.best_fitness), topo

    # two-pass kernels on the island mesh: hoisted+combined reduction
    # matches the single-device island run closely
    for kern in ("pearson", "r2"):
        sm = GPSession(pop_size=16, generations=1, kernel=kern, islands=2,
                       topology=MeshTopology(data=2, model=2, pod=2))
        sm.ingest(X_rows, y)
        sm.init(key=jax.random.PRNGKey(3))
        sm.step()
        ss = GPSession(pop_size=16, generations=1, kernel=kern, islands=2,
                       backend="jnp")
        ss.ingest(X_rows, y)
        ss.init(key=jax.random.PRNGKey(3))
        ss.step()
        np.testing.assert_allclose(np.asarray(sm.state.fitness),
                                   np.asarray(ss.state.fitness),
                                   rtol=1e-4, atol=1e-4, err_msg=kern)
    print("ISLAND_MESH_OK")
""")


@pytest.mark.tier2
def test_island_mesh_subprocess():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROCESS_ISLAND_MESH], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "ISLAND_MESH_OK" in r.stdout
