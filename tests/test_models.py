"""Per-arch smoke tests (reduced configs) + cross-path consistency:
prefill+decode must reproduce the training-path logits position by
position, SSD must match the naive recurrence, MoE must match a dense
reference when capacity is unbounded."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_names, get_reduced
from repro.models import model as Md
from repro.models import moe as MoE
from repro.models import ssm as SSM
from repro.optim.adamw import for_config


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.RandomState(seed)
    toks = rng.randint(0, cfg.vocab, size=(B, S + 1)).astype(np.int32)
    b = {"tokens": jnp.asarray(toks[:, :-1]), "labels": jnp.asarray(toks[:, 1:]),
         "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.family == "encdec":
        b["frames"] = jnp.asarray(rng.randn(B, S, cfg.d_model).astype(np.float32) * 0.02,
                                  jnp.bfloat16)
    if cfg.family == "vlm":
        b["memory"] = jnp.asarray(rng.randn(B, cfg.n_memory, cfg.d_model)
                                  .astype(np.float32) * 0.02, jnp.bfloat16)
    return b


@pytest.mark.parametrize("name", all_arch_names())
def test_arch_smoke_train_step(name):
    cfg = get_reduced(name)
    params = Md.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = Md.forward_train(cfg, params, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0
    opt = for_config(cfg)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    step = jax.jit(Md.make_train_step(cfg, opt))
    state2, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"])) and float(m["grad_norm"]) > 0
    # params actually moved
    moved = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(state2["params"])))
    assert moved


@pytest.mark.parametrize("name", all_arch_names())
def test_arch_prefill_decode_shapes(name):
    cfg = get_reduced(name)
    params = Md.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    pf = {k: batch[k] for k in ("tokens", "frames", "memory") if k in batch}
    logits, cache = Md.prefill(cfg, params, pf, max_len=S + 4)
    assert logits.shape == (B, 1, cfg.vocab)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = Md.decode_step(cfg, params, cache, tok, jnp.asarray(S, jnp.int32))
    assert logits2.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("name", ["gemma-2b", "mamba2-370m", "qwen3-moe-30b-a3b",
                                  "jamba-1.5-large-398b"])
def test_decode_matches_forward(name):
    """Token-by-token decode must reproduce the chunked-training-path
    next-token logits (the strongest cross-path consistency check)."""
    cfg = get_reduced(name)
    cfg = dataclasses.replace(cfg, compute_dtype="float32", cache_dtype="float32",
                              moe_capacity_factor=8.0)  # no drops -> exact match
    params = Md.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 12
    batch = _batch(cfg, B, S)
    # teacher-forced decode over the same tokens
    pfx = 4
    pf = {"tokens": batch["tokens"][:, :pfx],
          **{k: batch[k] for k in ("frames", "memory") if k in batch}}
    _, cache = Md.prefill(cfg, params, pf, max_len=S + 2)
    got = []
    for t in range(pfx, S):
        logits, cache = Md.decode_step(cfg, params, cache,
                                       batch["tokens"][:, t:t + 1],
                                       jnp.asarray(t, jnp.int32))
        got.append(np.asarray(logits[0, 0], np.float32))
    # reference: full-sequence training forward, logits at each position
    from repro.models import transformer as T
    p = params
    dt = jnp.float32
    x = T.embed_tokens(cfg, Md._cast(p["tok"], dt), batch["tokens"])
    if cfg.pos_embed == "sinusoidal":
        x = x + Md._sinusoidal(S, cfg.d_model, x.dtype)[None]
    memory = Md._encode_memory(cfg, Md._cast(p, dt), batch)
    x, _ = T.stack_apply_train(cfg, Md._cast(p["stack"], dt), x, cfg.pattern,
                               memory=memory)
    x = T._apply_norm(cfg, Md._cast(p["final_norm"], dt), x)
    W = p["tok"]["embed"].T if cfg.tie_embeddings else p["tok"]["unembed"]
    ref_logits = np.asarray(jnp.einsum("bsd,dv->bsv", x, W.astype(dt)), np.float32)
    for i, t in enumerate(range(pfx, S)):
        np.testing.assert_allclose(got[i], ref_logits[0, t], rtol=2e-3, atol=2e-3)


def test_ssd_matches_naive_recurrence():
    """Chunked SSD (dual form) == step-by-step linear recurrence."""
    rng = np.random.RandomState(0)
    b, l, h, p, g, n = 2, 16, 4, 8, 2, 16
    x = jnp.asarray(rng.randn(b, l, h, p).astype(np.float32) * 0.5)
    dt = jnp.asarray(np.abs(rng.randn(b, l, h)).astype(np.float32) * 0.5)
    A_log = jnp.asarray(rng.randn(h).astype(np.float32) * 0.3)
    B = jnp.asarray(rng.randn(b, l, g, n).astype(np.float32) * 0.5)
    C = jnp.asarray(rng.randn(b, l, g, n).astype(np.float32) * 0.5)
    D = jnp.asarray(rng.randn(h).astype(np.float32))
    y_chunk, final = SSM.ssd_chunked(x, dt, A_log, B, C, D, chunk=4)
    # naive recurrence
    A = -np.exp(np.asarray(A_log))
    Bh = np.repeat(np.asarray(B), h // g, axis=2)
    Ch = np.repeat(np.asarray(C), h // g, axis=2)
    hstate = np.zeros((b, h, n, p), np.float64)
    ys = np.zeros((b, l, h, p), np.float64)
    xn, dtn = np.asarray(x, np.float64), np.asarray(dt, np.float64)
    for t in range(l):
        dA = np.exp(A[None] * dtn[:, t])  # [b,h]
        upd = (dtn[:, t, :, None] * Bh[:, t])[..., :, None] * xn[:, t][:, :, None, :]
        hstate = hstate * dA[..., None, None] + upd
        ys[:, t] = np.einsum("bhn,bhnp->bhp", Ch[:, t], hstate)
    ys = ys + np.asarray(D)[None, None, :, None] * xn
    np.testing.assert_allclose(np.asarray(y_chunk), ys, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(final), hstate, rtol=2e-3, atol=2e-3)


def test_ssm_decode_matches_prefill():
    dims = SSM.SSMDims(d_model=32, d_state=16, headdim=8, n_groups=1, chunk=4)
    p = SSM.ssm_init(jax.random.PRNGKey(0), dims)
    x = jnp.asarray(np.random.RandomState(1).randn(2, 12, 32).astype(np.float32) * 0.3)
    y_all, final, conv_tail = SSM.ssm_apply(p, x, dims)
    # decode the same sequence token by token
    ssm_state = jnp.zeros((2, dims.n_heads, dims.d_state, dims.headdim), jnp.float32)
    conv_state = jnp.zeros((2, dims.d_conv - 1, dims.conv_dim), jnp.float32)
    outs = []
    for t in range(12):
        y, ssm_state, conv_state = SSM.ssm_decode(p, x[:, t:t + 1], ssm_state,
                                                  conv_state, dims)
        outs.append(np.asarray(y[:, 0]))
    np.testing.assert_allclose(np.stack(outs, 1), np.asarray(y_all),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(ssm_state), np.asarray(final),
                               rtol=2e-3, atol=2e-3)


def test_moe_matches_dense_reference():
    """With top_k == n_experts and generous capacity, MoE output equals the
    probability-weighted sum of every expert's dense FFN."""
    d, ff, E = 16, 32, 4
    p = MoE.moe_init(jax.random.PRNGKey(0), d, ff, E)
    x = jnp.asarray(np.random.RandomState(0).randn(2, 8, d).astype(np.float32) * 0.5)
    y, aux = MoE.moe_apply(p, x, top_k=E, capacity_factor=4.0)
    xt = np.asarray(x).reshape(16, d)
    probs = np.asarray(jax.nn.softmax(xt @ np.asarray(p["router"]), axis=-1))
    ref = np.zeros_like(xt)
    for e in range(E):
        g = xt @ np.asarray(p["w_gate"][e])
        u = xt @ np.asarray(p["w_up"][e])
        h = (g * (1 / (1 + np.exp(-g)))) * u  # silu(g)*u
        ref += probs[:, e:e + 1] * (h @ np.asarray(p["w_down"][e]))
    np.testing.assert_allclose(np.asarray(y).reshape(16, d), ref, rtol=2e-3, atol=2e-3)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_are_bounded():
    d, ff, E = 8, 16, 4
    p = MoE.moe_init(jax.random.PRNGKey(1), d, ff, E)
    x = jnp.asarray(np.random.RandomState(1).randn(4, 16, d).astype(np.float32))
    y, _ = MoE.moe_apply(p, x, top_k=2, capacity_factor=1.0)
    assert np.isfinite(np.asarray(y)).all()


def test_long_500k_support_flags():
    from repro.configs import get_config
    sub = {n: get_config(n).subquadratic for n in all_arch_names()}
    assert sub["mamba2-370m"] and sub["jamba-1.5-large-398b"]
    assert sum(sub.values()) == 2  # everything else skips long_500k
    for n, s in sub.items():
        assert Md.shape_supported(get_config(n), "long_500k") == s
