"""Expression parser / seed populations + new engine features."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import GPConfig, TreeSpec, FitnessSpec, init_state, run
from repro.core import primitives as prim
from repro.core.parse import parse_tree, seed_population
from repro.core.trees import check_invariants, generate_population, to_string


def test_parse_simple():
    spec = TreeSpec(max_depth=3, n_features=2, n_consts=8)
    op, arg = parse_tree("((x0 * x0) / x1)", spec)
    assert op[0] == prim.opcode_of("div")
    assert op[1] == prim.opcode_of("mul")
    assert op[2] == prim.FEATURE and arg[2] == 1


def test_parse_functions_and_consts():
    spec = TreeSpec(max_depth=3, n_features=1, n_consts=8,
                    fn_set=prim.KITCHEN_SINK)
    op, arg = parse_tree("sqrt(max(x0, 2))", spec)
    assert op[0] == prim.opcode_of("sqrt")
    assert op[1] == prim.opcode_of("max")
    consts = np.asarray(spec.const_table())
    assert np.isclose(consts[arg[4]], 2.0)


def test_parse_feature_names():
    spec = TreeSpec(max_depth=2, n_features=2, n_consts=8)
    op, arg = parse_tree("(p + r)", spec, feature_names=["p", "r"])
    assert arg[1] == 0 and arg[2] == 1


def test_parse_errors():
    spec = TreeSpec(max_depth=2, n_features=1, n_consts=4)
    with pytest.raises(ValueError):
        parse_tree("(x0 + x9)", spec)  # unknown feature
    with pytest.raises(ValueError):
        parse_tree("frob(x0)", spec)  # unknown function
    with pytest.raises(ValueError):
        parse_tree("(((x0+x0)+(x0+x0))+x0)", spec)  # too deep


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), depth=st.integers(1, 4))
def test_to_string_parse_roundtrip(seed, depth):
    """to_string → parse_tree reproduces evaluation-identical trees."""
    from repro.core.eval import evaluate_population

    spec = TreeSpec(max_depth=depth, n_features=3, n_consts=8,
                    fn_set=prim.KITCHEN_SINK)
    op, arg = generate_population(jax.random.PRNGKey(seed), 4, spec)
    consts = np.asarray(spec.const_table())
    X = jnp.asarray(np.random.RandomState(0).randn(3, 16).astype(np.float32))
    want = np.asarray(evaluate_population(op, arg, X, spec.const_table(), spec))
    for i in range(4):
        s = to_string(np.asarray(op[i]), np.asarray(arg[i]), const_table=consts)
        op2, arg2 = parse_tree(s, spec)
        got = np.asarray(evaluate_population(jnp.asarray(op2[None]),
                                             jnp.asarray(arg2[None]), X,
                                             spec.const_table(), spec))[0]
        np.testing.assert_allclose(got, want[i], rtol=1e-5, atol=1e-5)


def test_seed_population_and_early_stop():
    """Seeding the known Kepler solution terminates generation 0."""
    from repro.data.datasets import kepler
    from repro.data.loader import feature_major

    X_rows, y, _ = kepler()
    spec = TreeSpec(max_depth=5, n_features=1, n_consts=8,
                    fn_set=prim.KITCHEN_SINK)
    cfg = GPConfig(pop_size=32, tree_spec=spec, fitness=FitnessSpec("r"),
                   generations=30, stop_fitness=1.0)
    state = run(cfg, feature_major(X_rows), y, key=jax.random.PRNGKey(0),
                seeds=["sqrt(((r * r) * r))"], feature_names=["r"])
    assert int(state.generation) == 1  # stopped immediately
    assert float(state.best_fitness) < 1.0


def test_seeded_population_valid():
    spec = TreeSpec(max_depth=4, n_features=2, n_consts=8)
    op, arg = seed_population(["(x0 + x1)", "(x0 * 2)"], spec, 16,
                              jax.random.PRNGKey(0))
    check_invariants(np.asarray(op), spec)


def test_parsimony_prefers_smaller_trees():
    """With heavy parsimony pressure, mean tree size stays below the
    pressure-free run (bloat control beyond the depth ceiling)."""
    from repro.core.trees import tree_sizes
    from repro.data.datasets import kepler
    from repro.data.loader import feature_major

    X_rows, y, _ = kepler()
    spec = TreeSpec(max_depth=5, n_features=1, n_consts=8)
    base = dict(pop_size=60, tree_spec=spec, fitness=FitnessSpec("r"),
                generations=10)
    s_free = run(GPConfig(**base), feature_major(X_rows), y,
                 key=jax.random.PRNGKey(3))
    s_press = run(GPConfig(parsimony=5.0, **base), feature_major(X_rows), y,
                  key=jax.random.PRNGKey(3))
    assert float(jnp.mean(tree_sizes(s_press.op))) <= \
        float(jnp.mean(tree_sizes(s_free.op)))


def test_cluster_env_parsing():
    from repro.launch.cluster import ClusterInfo, cluster_env, host_batch_slice

    info = cluster_env({"COORDINATOR_ADDRESS": "10.0.0.1:1234",
                        "NUM_PROCESSES": "8", "PROCESS_ID": "3"})
    assert info.num_processes == 8 and info.process_id == 3
    assert not info.is_coordinator
    assert host_batch_slice(256, info) == slice(96, 128)
    slurm = cluster_env({"SLURM_NTASKS": "4", "SLURM_PROCID": "2",
                         "SLURM_NODELIST": "tpu[0-3]"})
    assert slurm.num_processes == 4 and slurm.process_id == 2
    single = cluster_env({})
    assert single.num_processes == 1 and single.is_coordinator
    with pytest.raises(ValueError):
        host_batch_slice(10, ClusterInfo(3, 0, None))


def test_evolve_driver_checkpoint_resume(tmp_path):
    """The GP driver resumes mid-run from the newest committed checkpoint
    and reaches the same final state as an uninterrupted run."""
    from repro.launch.evolve import run_dataset

    full, _, _ = run_dataset("kepler", generations=10, pop=30, log=lambda *a: None)
    part, _, _ = run_dataset("kepler", generations=6, pop=30,
                             ckpt_dir=str(tmp_path), ckpt_every=3,
                             log=lambda *a: None)
    resumed, _, _ = run_dataset("kepler", generations=10, pop=30,
                                ckpt_dir=str(tmp_path), ckpt_every=3,
                                log=lambda *a: None)
    assert int(resumed.generation) == 10
    # resumed run continues from gen 6's state (same RNG stream → same result)
    np.testing.assert_array_equal(np.asarray(resumed.best_op),
                                  np.asarray(full.best_op))
