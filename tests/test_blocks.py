"""Device-resident evolution blocks: scan-block vs step-by-step equivalence
(single-device and mesh), padding-exact weighted evaluation on every
backend × kernel, on-device early stop, and the block-driving session's
host-sync budget (one synchronization per block)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FitnessSpec, GPConfig, TreeSpec, evolve_block, evolve_step, init_state,
)
from repro.core import fitness as fit
from repro.core.trees import generate_population
from repro.data.datasets import kepler
from repro.data.loader import feature_major, pad_feature_major
from repro.gp import GPSession, get_backend


def _kepler_setup(pop=24, depth=4):
    X_rows, y, _ = kepler()
    spec = TreeSpec(max_depth=depth, n_features=1, n_consts=8)
    cfg = GPConfig(pop_size=pop, tree_spec=spec, fitness=FitnessSpec("r"))
    return cfg, jnp.asarray(feature_major(X_rows)), jnp.asarray(y)


# --- scan-block vs step-by-step ----------------------------------------------


def test_block_bitwise_identical_to_stepwise():
    """K scanned generations == K dispatched generations, bit for bit:
    same PRNG stream, same state pytree. The scan shares the step's body,
    so the device-resident loop cannot drift from the reference loop."""
    cfg, X, y = _kepler_setup()
    K = 7
    s_step = init_state(cfg, jax.random.PRNGKey(0))
    for _ in range(K):
        s_step = evolve_step(cfg, s_step, X, y)
    s_blk, hist, counters = evolve_block(
        cfg, init_state(cfg, jax.random.PRNGKey(0)), X, y, None, n_steps=K)
    for name, a, b in zip(s_step._fields, jax.tree.leaves(s_step),
                          jax.tree.leaves(s_blk)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"GPState.{name} diverged")
    assert hist.shape == (K,)
    assert float(hist[-1]) == float(s_step.best_fitness)
    assert counters.shape == (K, 7)  # telemetry stream rides the same scan


def test_block_early_stop_freezes_on_device():
    """Once best_fitness <= stop_fitness, the remaining scan steps are
    no-ops: generation stops advancing and the state (PRNG key included)
    is carried unchanged — the host can detect the stop from the
    generation counter alone, at the block boundary."""
    import dataclasses

    cfg, X, y = _kepler_setup()
    cfg = dataclasses.replace(cfg, stop_fitness=1e9)  # stops after gen 1
    state, hist, counters = evolve_block(
        cfg, init_state(cfg, jax.random.PRNGKey(0)), X, y, None, n_steps=10)
    assert int(state.generation) == 1
    assert np.all(np.asarray(hist) == np.asarray(hist)[0])
    # frozen steps self-report in the counter stream (column 2)
    assert int(np.asarray(counters)[:, 2].sum()) == 9


def test_session_one_sync_per_block():
    """The step()/evolve() contract drift fixed: a multi-generation
    evolve() on a jitted backend issues at most one host synchronization
    per evolution block — ⌈G/K⌉ total, and exactly ONE for the default
    whole-run block."""
    X_rows, y, _ = kepler()
    s = GPSession(pop_size=24, generations=50, kernel="r", backend="jnp",
                  block_size=10)
    s.fit(X_rows, y, key=jax.random.PRNGKey(0))
    assert s.generation == 50 and len(s.history) == 50
    assert s.stats["host_syncs"] <= -(-50 // 10), s.stats

    s2 = GPSession(pop_size=24, generations=50, kernel="r", backend="jnp")
    s2.fit(X_rows, y, key=jax.random.PRNGKey(0))
    assert s2.stats["host_syncs"] == 1, s2.stats
    # identical trajectory regardless of block partitioning
    np.testing.assert_array_equal(np.asarray(s.history), np.asarray(s2.history))


def test_session_callback_and_checkpoint_set_block_span():
    """Block size respects the callback/checkpoint periods, so host-side
    side effects still fire exactly as configured."""
    X_rows, y, _ = kepler()
    seen = []
    s = GPSession(pop_size=16, generations=12, kernel="r", backend="jnp",
                  callback=lambda g, st: seen.append(g), callback_every=4)
    s.fit(X_rows, y, key=jax.random.PRNGKey(0))
    assert seen == [3, 7, 11]
    assert s.stats["blocks"] == 3 and len(s.history) == 12


def test_checkpoint_period_phase_aligns_with_blocks(tmp_path):
    """Periodic checkpoints fire on their configured multiples even when
    another period forces misaligned block boundaries: checkpoint_every=4
    with callback_every=3 → boundaries 3,4,6,8,9,12 and saves at 4,8,12."""
    X_rows, y, _ = kepler()
    s = GPSession(pop_size=16, generations=12, kernel="r", backend="jnp",
                  checkpoint_dir=str(tmp_path), checkpoint_every=4,
                  callback=lambda g, st: None, callback_every=3)
    s.fit(X_rows, y, key=jax.random.PRNGKey(0))
    s._manager.wait()
    assert sorted(s._manager.saved_steps) == [4, 8, 12], s._manager.saved_steps


def test_callback_every_honored_on_host_backend():
    """The scalar host loop fires the callback on the callback_every
    cadence (plus the final generation), not every generation."""
    X_rows, y, _ = kepler()
    seen = []
    s = GPSession(pop_size=12, generations=5, kernel="r", backend="scalar",
                  callback=lambda g, st: seen.append(g), callback_every=2)
    s.fit(X_rows, y)
    assert seen == [1, 3, 4], seen


def test_raw_evolve_block_then_evolve_stays_coherent():
    """Mixing the raw evolve_block() surface with evolve() keeps the
    host's generation mirror coherent — including under stop_fitness,
    where frozen steps mean the device counter can lag the dispatch
    count (evolve() resyncs once instead of crashing/desyncing)."""
    X_rows, y, _ = kepler()
    s = GPSession(pop_size=16, generations=30, kernel="r", backend="jnp",
                  stop_fitness=-1.0)  # unreachable: no freeze, but traced
    s.ingest(X_rows, y)
    s.init(key=jax.random.PRNGKey(0))
    s.evolve_block(5)
    s.evolve(10)
    assert s.generation == 15 and len(s.history) == 10

    s2 = GPSession(pop_size=16, generations=30, kernel="r", backend="jnp",
                   stop_fitness=1e9)  # stops after generation 1
    s2.ingest(X_rows, y)
    s2.init(key=jax.random.PRNGKey(0))
    s2.evolve_block(5)  # device froze at gen 1; host mirror marked stale
    s2.evolve(10)
    assert s2.generation == 1  # resynced, not 5 + garbage


def test_unreached_stop_fitness_runs_all_generations():
    """An armed-but-never-reached stop_fitness must not shorten the run:
    the block span is capped at the compiled quantum (_STOP_CHECK_SPAN),
    so `ran < K` only ever signals a real on-device freeze — previously
    K could exceed the dispatched block length and a full 32-step block
    was misread as an early stop, silently truncating generations."""
    X_rows, y, _ = kepler()
    s = GPSession(pop_size=16, generations=100, kernel="r", backend="jnp",
                  stop_fitness=-1.0)  # unreachable
    s.fit(X_rows, y, key=jax.random.PRNGKey(0))
    assert s.generation == 100, s.generation
    assert len(s.history) == 100
    assert s.stats["blocks"] == -(-100 // GPSession._STOP_CHECK_SPAN)


def test_stop_fitness_bounds_block_span():
    """Frozen steps still execute on-device, so with stop_fitness armed
    and no other period the session caps blocks at _STOP_CHECK_SPAN: a
    run converging early overshoots at most one capped block."""
    X_rows, y, _ = kepler()
    s = GPSession(pop_size=16, generations=500, kernel="r", backend="jnp",
                  stop_fitness=1e9)  # stops after generation 1
    s.fit(X_rows, y, key=jax.random.PRNGKey(0))
    assert s.generation == 1
    assert s.stats["blocks"] == 1  # one capped block, not a 500-step scan


def test_ragged_blocks_reuse_one_compiled_program():
    """Phase-aligned boundaries produce ragged block lengths; the session
    must serve them all from ONE fixed-length compiled scan (dynamic
    limit), not one compile per distinct length."""
    from repro.core import engine

    X_rows, y, _ = kepler()
    s = GPSession(pop_size=16, generations=17, kernel="r", backend="jnp",
                  callback=lambda g, st: None, callback_every=7)
    s.ingest(X_rows, y)
    s.init(key=jax.random.PRNGKey(0))
    n0 = engine.evolve_block._cache_size()
    s.evolve()  # boundaries at 7, 14, 17 → lengths 7, 7, 3
    assert s.generation == 17 and s.stats["blocks"] == 3
    assert engine.evolve_block._cache_size() == n0 + 1


# --- padding-exact weighted evaluation ---------------------------------------


@pytest.mark.parametrize("backend", ["jnp", "pallas", "scalar"])
@pytest.mark.parametrize("kernel", ["r", "c", "m", "mse", "pearson", "r2"])
def test_padded_fitness_matches_unpadded(backend, kernel):
    """fitness on zero-weighted padded [D+r] data == fitness on the
    unpadded [D] data, for every registered kernel on every backend —
    the guarantee that lets any dataset shard on any data axis."""
    spec = TreeSpec(max_depth=4, n_features=4, n_consts=8)
    op, arg = generate_population(jax.random.PRNGKey(3), 16, spec)
    rng = np.random.RandomState(0)
    X = rng.randn(4, 101).astype(np.float32)  # odd D: pads to 112 (tile 8)
    y = rng.randint(0, 3, 101).astype(np.float32)
    Xp, yp, w = pad_feature_major(X, y, 8)
    assert Xp.shape[1] != X.shape[1]  # padding actually happened
    fs = FitnessSpec(kernel, n_classes=3, precision=0.5)
    consts = np.asarray(spec.const_table())
    be = get_backend(backend)
    base = np.asarray(be.fitness(op, arg, X, y, consts, spec, fs))
    padded = np.asarray(be.fitness(op, arg, Xp, yp, consts, spec, fs,
                                   weight=jnp.asarray(w)))
    np.testing.assert_allclose(padded, base, rtol=1e-5, atol=1e-5)


def test_weighted_partials_all_kernels_direct():
    """FitnessKernel.partial_fitness itself ignores zero-weight points —
    including the two-pass pearson/r2 kernels' global moments."""
    rng = np.random.RandomState(1)
    preds = jnp.asarray(rng.randn(5, 64).astype(np.float32))
    y = jnp.asarray(rng.randn(64).astype(np.float32))
    pad = jnp.asarray(rng.randn(5, 16).astype(np.float32))
    preds_p = jnp.concatenate([preds, pad], axis=1)
    y_p = jnp.concatenate([y, jnp.zeros(16)])
    w = jnp.concatenate([jnp.ones(64), jnp.zeros(16)])
    for kernel in fit.available_kernels():
        spec = FitnessSpec(kernel, n_classes=3, precision=0.5)
        base = np.asarray(fit.fitness_from_preds(preds, y, spec))
        padded = np.asarray(fit.fitness_from_preds(preds_p, y_p, spec, weight=w))
        np.testing.assert_allclose(padded, base, rtol=1e-5, atol=1e-5,
                                   err_msg=f"kernel {kernel!r}")


# --- mesh: scan-inside-shard_map + padded sharding (subprocess) --------------

_SUBPROCESS_MESH_BLOCKS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro import compat
    from repro.core import (GPConfig, TreeSpec, FitnessSpec, init_state,
                            sharded_evolve_step, sharded_evolve_block)
    from repro.core.engine import evolve_step
    from repro.launch.mesh import make_host_mesh
    from repro.gp import GPSession, MeshTopology

    spec = TreeSpec(max_depth=4, n_features=2, n_consts=8)
    cfg = GPConfig(pop_size=32, tree_spec=spec, fitness=FitnessSpec("r"))
    rng = np.random.RandomState(1)
    Xk = np.abs(rng.randn(2, 128)).astype(np.float32) + 0.5
    yk = (Xk[0]**2 / Xk[1]).astype(np.float32)
    X, y = jnp.asarray(Xk), jnp.asarray(yk)
    w = jnp.ones((128,), jnp.float32)

    # scan-inside-shard_map block == K dispatched sharded steps, bitwise
    mesh = make_host_mesh(data=2, model=2, pod=2)
    step, _ = sharded_evolve_step(cfg, mesh, pod_axis="pod")
    block, _ = sharded_evolve_block(cfg, mesh, n_steps=6, pod_axis="pod")
    s_step = init_state(cfg, jax.random.PRNGKey(0))
    with compat.set_mesh(mesh):
        js = jax.jit(step)
        for _ in range(6):
            s_step = js(s_step, X, y, w)
        s_blk, hist, counters = jax.jit(block)(
            init_state(cfg, jax.random.PRNGKey(0)), X, y, w,
            jnp.asarray(6, jnp.int32))
    for name, a, b in zip(s_step._fields, jax.tree.leaves(s_step), jax.tree.leaves(s_blk)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg="GPState." + name)
    assert hist.shape == (6,)
    assert float(np.asarray(hist)[-1]) == float(s_step.best_fitness)

    # acceptance: odd rows shard on data=2 — padded, masked, and the
    # evaluated fitness matches the unpadded single-device computation
    X_rows = np.ascontiguousarray(Xk.T)[:101]   # 101 % 2 == 1
    y101 = yk[:101]
    sm = GPSession(pop_size=32, generations=1, kernel="r",
                   topology=MeshTopology(data=2))
    sm.ingest(X_rows, y101)
    sm.init(key=jax.random.PRNGKey(2))
    sm.step()
    ss = GPSession(pop_size=32, generations=1, kernel="r", backend="jnp")
    ss.ingest(X_rows, y101)
    ss.init(key=jax.random.PRNGKey(2))
    ss.step()
    np.testing.assert_allclose(np.asarray(sm.state.fitness),
                               np.asarray(ss.state.fitness), rtol=1e-5, atol=1e-5)
    assert float(sm.state.best_fitness) == float(ss.state.best_fitness) or (
        abs(float(sm.state.best_fitness) - float(ss.state.best_fitness)) < 1e-5)

    # and a full padded mesh fit() drives blocks end to end
    sm2 = GPSession(pop_size=32, generations=10, kernel="r",
                    topology=MeshTopology(data=2, model=2))
    sm2.fit(X_rows, y101)
    assert sm2.generation == 10 and np.isfinite(sm2.best_fitness)
    assert sm2.stats["host_syncs"] == 1, sm2.stats

    # two-pass kernels (pearson, r2) on the mesh data axis: the merged
    # (hoisted + Chan-combined) moments must match the single-device
    # fitness, on unpadded (128) and padded ragged (101 -> 104 on data=4)
    # datasets alike. Centered moments killed the old raw-moment rounding
    # amplification, so BOTH kernels now hold 1e-4 (pearson was 5e-3).
    tol = {"pearson": 1e-4, "r2": 1e-4}
    for kern in ("pearson", "r2"):
        for rows in (128, 101):
            Xr, yr = np.ascontiguousarray(Xk.T)[:rows], yk[:rows]
            sm = GPSession(pop_size=32, generations=1, kernel=kern,
                           topology=MeshTopology(data=4, model=2))
            sm.ingest(Xr, yr)
            sm.init(key=jax.random.PRNGKey(3))
            sm.step()
            ss = GPSession(pop_size=32, generations=1, kernel=kern, backend="jnp")
            ss.ingest(Xr, yr)
            ss.init(key=jax.random.PRNGKey(3))
            ss.step()
            np.testing.assert_allclose(
                np.asarray(sm.state.fitness), np.asarray(ss.state.fitness),
                rtol=tol[kern], atol=tol[kern],
                err_msg="mesh-vs-single %s rows=%d" % (kern, rows))
    print("MESH_BLOCKS_OK")
""")


@pytest.mark.tier2
def test_mesh_blocks_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROCESS_MESH_BLOCKS], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MESH_BLOCKS_OK" in r.stdout


# --- scalar host loop: cached selection program ------------------------------


def test_host_next_generation_cached_across_sessions():
    """The scalar backend's host loop re-enters ONE jitted selection
    program per (spec, mix, tourn_size, elitism) — no per-call-site
    retrace (ROADMAP open item)."""
    from repro.gp import backends as B

    X_rows, y, _ = kepler()
    B.host_next_generation.cache_clear()
    s1 = GPSession(pop_size=12, generations=2, kernel="r", backend="scalar")
    s1.fit(X_rows, y)
    s2 = GPSession(pop_size=12, generations=2, kernel="r", backend="scalar")
    s2.fit(X_rows, y)
    info = B.host_next_generation.cache_info()
    assert info.misses == 1 and info.hits >= 3, info
    fn = B.host_next_generation(s1.config.tree_spec, s1.config.mix,
                                s1.config.tourn_size, s1.config.elitism)
    assert fn._cache_size() == 1  # one compiled program across 4 generations
