"""Population generation + genetic-operator structural invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import evolve as ev
from repro.core import primitives as prim
from repro.core.trees import (TreeSpec, check_invariants, depth_table,
                              generate_population, subtree_mask_table, to_string,
                              tree_sizes)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), depth=st.integers(1, 6),
       pop=st.sampled_from([1, 7, 32]), nf=st.integers(1, 9))
def test_generation_invariants(seed, depth, pop, nf):
    spec = TreeSpec(max_depth=depth, n_features=nf, n_consts=4)
    op, arg = generate_population(jax.random.PRNGKey(seed), pop, spec)
    check_invariants(np.asarray(op), spec)
    # args in range
    a = np.asarray(arg)
    o = np.asarray(op)
    assert (a[o == prim.FEATURE] < nf).all() and (a[o == prim.FEATURE] >= 0).all()
    assert (a[o == prim.CONST] < 4).all()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_crossover_preserves_invariants(seed):
    spec = TreeSpec(max_depth=5, n_features=3, n_consts=4)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    op_a, arg_a = generate_population(k1, 16, spec)
    op_b, arg_b = generate_population(k2, 16, spec)
    op_c, arg_c = ev.crossover(k3, op_a, arg_a, op_b, arg_b, spec)
    check_invariants(np.asarray(op_c), spec)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_mutations_preserve_invariants(seed):
    spec = TreeSpec(max_depth=4, n_features=3, n_consts=4)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    op, arg = generate_population(k1, 16, spec)
    op_b, arg_b = ev.mutate_branch(k2, op, arg, spec)
    check_invariants(np.asarray(op_b), spec)
    op_p, arg_p = ev.mutate_point(k3, op, arg, spec, p=0.5)
    check_invariants(np.asarray(op_p), spec)


def test_next_generation_shapes_and_elitism():
    spec = TreeSpec(max_depth=5, n_features=2, n_consts=4)
    key = jax.random.PRNGKey(0)
    op, arg = generate_population(key, 32, spec)
    fitness = jnp.arange(32.0)  # tree 0 is best
    new_op, new_arg = ev.next_generation(key, op, arg, fitness, spec, elitism=1)
    assert new_op.shape == op.shape
    check_invariants(np.asarray(new_op), spec)
    np.testing.assert_array_equal(np.asarray(new_op[0]), np.asarray(op[0]))
    # n_out decoupling
    new_op, _ = ev.next_generation(key, op, arg, fitness, spec, elitism=0, n_out=8)
    assert new_op.shape == (8, spec.num_nodes)


def test_index_tables():
    N = 31
    d = depth_table(N)
    assert d[0] == 0 and d[1] == d[2] == 1 and d[30] == 4
    m = subtree_mask_table(N)
    assert m[0].all()  # root dominates everything
    assert m[1, 3] and m[1, 4] and not m[1, 5]
    assert m[3, 7] and m[3, 8] and not m[3, 9]


def test_to_string_and_sizes():
    spec = TreeSpec(max_depth=3, n_features=2, n_consts=4)
    op, arg = generate_population(jax.random.PRNGKey(1), 8, spec)
    s = to_string(np.asarray(op[0]), np.asarray(arg[0]),
                  const_table=np.asarray(spec.const_table()))
    assert isinstance(s, str) and len(s) > 0 and "∅" not in s
    sizes = np.asarray(tree_sizes(op))
    assert (sizes >= 1).all() and (sizes <= spec.num_nodes).all()


def test_tournament_prefers_fit():
    fitness = jnp.asarray(np.arange(64, dtype=np.float32))
    idx = ev.tournament(jax.random.PRNGKey(0), fitness, pop=512, size=10)
    # winners should be strongly biased toward low indices (minimization)
    assert np.asarray(idx).mean() < 16.0
