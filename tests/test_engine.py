"""Engine behaviour: convergence on paper problems + sharded-step subprocess
tests (multi-device CPU meshes must live in their own process so the main
pytest process keeps a single device)."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import GPConfig, TreeSpec, FitnessSpec, init_state, evolve_step, run
from repro.data.datasets import iris, kepler
from repro.data.loader import feature_major


def test_kepler_convergence():
    """The engine must rediscover Kepler's 3rd law (p = sqrt(r^3)) — the
    paper's flagship regression (fitness → ~0)."""
    X_rows, y, meta = kepler()
    from repro.core import primitives as prim
    spec = TreeSpec(max_depth=5, n_features=1, n_consts=8,
                    fn_set=prim.KITCHEN_SINK)
    cfg = GPConfig(pop_size=200, tree_spec=spec, fitness=FitnessSpec("r"),
                   generations=30)
    state = run(cfg, feature_major(X_rows), y, key=jax.random.PRNGKey(0))
    assert float(state.best_fitness) < 1.0  # sum|err| over 9 planets


def test_iris_classification_signal():
    X_rows, y, meta = iris()
    cfg = GPConfig(pop_size=100, tree_spec=TreeSpec(max_depth=5, n_features=4,
                                                    n_consts=8),
                   fitness=FitnessSpec("c", n_classes=3), generations=12)
    state = run(cfg, feature_major(X_rows), y, key=jax.random.PRNGKey(0))
    acc = -float(state.best_fitness) / 150.0
    assert acc > 0.60  # must beat chance (1/3) decisively


def test_pallas_impl_agrees_with_jnp():
    X_rows, y, meta = iris()
    X = feature_major(X_rows)
    spec = TreeSpec(max_depth=4, n_features=4, n_consts=8)
    base = dict(pop_size=40, tree_spec=spec,
                fitness=FitnessSpec("c", n_classes=3), generations=4)
    s1 = run(GPConfig(eval_impl="jnp", **base), X, y, key=jax.random.PRNGKey(5))
    s2 = run(GPConfig(eval_impl="pallas", **base), X, y, key=jax.random.PRNGKey(5))
    assert float(s1.best_fitness) == pytest.approx(float(s2.best_fitness), abs=1e-3)


_SUBPROCESS_SHARDED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import GPConfig, TreeSpec, FitnessSpec, init_state, sharded_evolve_step, evolve_step
    from repro.launch.mesh import make_host_mesh
    from repro import compat

    spec = TreeSpec(max_depth=5, n_features=2, n_consts=8)
    cfg = GPConfig(pop_size=64, tree_spec=spec, fitness=FitnessSpec("r"),
                   migrate_every=3)
    Xk = np.abs(np.random.RandomState(1).randn(2, 128)).astype(np.float32) + 0.5
    yk = (Xk[0]**2 / Xk[1]).astype(np.float32)

    wk = jnp.ones((128,), jnp.float32)

    # 3D mesh with island model
    mesh = make_host_mesh(data=2, model=2, pod=2)
    step, specs = sharded_evolve_step(cfg, mesh, pod_axis="pod")
    s = init_state(cfg, jax.random.PRNGKey(0))
    with compat.set_mesh(mesh):
        js = jax.jit(step)
        for _ in range(12):
            s = js(s, jnp.asarray(Xk), jnp.asarray(yk), wk)
    assert np.isfinite(float(s.best_fitness)), s.best_fitness
    assert float(s.best_fitness) < 50.0
    assert int(s.generation) == 12

    # 2D mesh, same engine — and the single-device reference still improves
    mesh2 = make_host_mesh(data=4, model=2)
    step2, _ = sharded_evolve_step(cfg, mesh2)
    s2 = init_state(cfg, jax.random.PRNGKey(0))
    with compat.set_mesh(mesh2):
        js2 = jax.jit(step2)
        for _ in range(12):
            s2 = js2(s2, jnp.asarray(Xk), jnp.asarray(yk), wk)
    assert np.isfinite(float(s2.best_fitness))
    print("SHARDED_OK")
""")


@pytest.mark.tier2
def test_sharded_engine_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROCESS_SHARDED], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SHARDED_OK" in r.stdout


def test_state_is_checkpointable():
    from repro.ckpt.checkpoint import save, restore
    import tempfile
    cfg = GPConfig(pop_size=16, tree_spec=TreeSpec(max_depth=3, n_features=2),
                   fitness=FitnessSpec("r"))
    state = init_state(cfg, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        save(state, d, 7)
        back = restore(d, 7, like=state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
