"""Pallas kernel sweep: shapes × dtypes × fitness kernels × gather modes,
asserted allclose against the pure-jnp oracle (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fitness import FitnessSpec
from repro.core.trees import TreeSpec, generate_population
from repro.kernels import ops as kops
from repro.kernels.ref import fitness_ref


def _case(depth, F, D, pop, seed):
    spec = TreeSpec(max_depth=depth, n_features=F, n_consts=8)
    op, arg = generate_population(jax.random.PRNGKey(seed), pop, spec)
    X = jnp.asarray(np.random.RandomState(seed).randn(F, D).astype(np.float32))
    y = jnp.asarray((np.random.RandomState(seed + 1).rand(D) * 3).astype(np.float32))
    return spec, op, arg, X, y


@pytest.mark.parametrize("depth", [2, 3, 5])
@pytest.mark.parametrize("F,D", [(1, 9), (2, 37), (9, 500), (16, 1030)])
@pytest.mark.parametrize("gather", ["onehot", "vmem"])
def test_kernel_matches_oracle(depth, F, D, gather):
    spec, op, arg, X, y = _case(depth, F, D, pop=21, seed=depth * 100 + F)
    fs = FitnessSpec("r")
    got = kops.fitness(op, arg, X, y, spec.const_table(), spec, fs, gather=gather)
    want = fitness_ref(op, arg, X, y, spec.const_table(), spec, fs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("kern,kw", [("c", dict(n_classes=3)),
                                     ("m", dict(precision=0.5))])
def test_kernel_classify_match(kern, kw):
    spec, op, arg, X, y = _case(4, 4, 150, pop=16, seed=7)
    fs = FitnessSpec(kern, **kw)
    got = kops.fitness(op, arg, X, y, spec.const_table(), spec, fs)
    want = fitness_ref(op, arg, X, y, spec.const_table(), spec, fs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_kernel_large_feature_count():
    """LIGO-shaped: F=1373 forces the vmem-gather path + small data tiles."""
    spec, op, arg, X, y = _case(5, 1373, 256, pop=8, seed=11)
    fs = FitnessSpec("c", n_classes=2)
    got = kops.fitness(op, arg, X, y, spec.const_table(), spec, fs)
    want = fitness_ref(op, arg, X, y, spec.const_table(), spec, fs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_kernel_dtype_bf16_data():
    spec, op, arg, X, y = _case(3, 4, 128, pop=8, seed=3)
    fs = FitnessSpec("r")
    got = kops.fitness(op, arg, X.astype(jnp.bfloat16), y, spec.const_table(), spec, fs)
    want = fitness_ref(op, arg, X.astype(jnp.bfloat16).astype(jnp.float32), y,
                       spec.const_table(), spec, fs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-2)


def test_tile_picker_respects_budget():
    from repro.kernels.ops import pick_tiles, _VMEM_BUDGET
    for F in (2, 64, 1373):
        pb, db, gather = pick_tiles(F, 63, 100, 1 << 20)
        assert db >= 128
        base = 4 * (F * db + 2 * pb * 64 * db)
        assert base <= _VMEM_BUDGET * 1.05
