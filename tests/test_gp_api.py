"""repro.gp public API: backend parity across the registry, fitness-kernel
registration/dispatch (incl. NaN sanitization), GPSession front door,
topology subprocess run, and the core.run deprecation shim."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fitness as fit
from repro.core.trees import TreeSpec, generate_population
from repro.data.datasets import iris, kepler
from repro.gp import (
    FitnessKernel, FitnessSpec, GPSession, MeshTopology, SymbolicClassifier,
    SymbolicRegressor, available_backends, available_kernels, get_backend,
    register_kernel,
)


@pytest.fixture(scope="module")
def fixed_population():
    spec = TreeSpec(max_depth=4, n_features=4, n_consts=8)
    op, arg = generate_population(jax.random.PRNGKey(3), 24, spec)
    X_rows, y, _ = iris()
    X_rows, y = X_rows[:64], y[:64]
    X = np.ascontiguousarray(X_rows.T)
    return spec, op, arg, X, y


# --- backend parity ----------------------------------------------------------


def test_backend_registry_contents():
    assert {"scalar", "jnp", "pallas"} <= set(available_backends())
    assert get_backend("scalar").jittable is False
    assert get_backend("pallas").fused_fitness is True
    with pytest.raises(ValueError, match="unknown eval backend"):
        get_backend("cuda")


@pytest.mark.parametrize("kernel", ["r", "c", "m", "mse"])
def test_backend_parity_on_fixed_population(fixed_population, kernel):
    """scalar, jnp and pallas(interpret) must agree on fitness for the same
    population — the paper's claim that platforms differ only in speed."""
    spec, op, arg, X, y = fixed_population
    fs = FitnessSpec(kernel, n_classes=3, precision=0.5)
    consts = np.asarray(spec.const_table())
    results = {
        name: np.asarray(get_backend(name).fitness(op, arg, X, y, consts, spec, fs))
        for name in ("scalar", "jnp", "pallas")
    }
    np.testing.assert_allclose(results["jnp"], results["scalar"], rtol=1e-5,
                               atol=1e-4)
    np.testing.assert_allclose(results["jnp"], results["pallas"], rtol=1e-5,
                               atol=1e-4)


def test_backend_parity_with_nan_data(fixed_population):
    """A NaN data point must poison the same trees to +inf on every backend."""
    spec, op, arg, X, y = fixed_population
    Xn = X.copy()
    Xn[:, 7] = np.nan
    consts = np.asarray(spec.const_table())
    for kernel in ("r", "c", "m"):
        fs = FitnessSpec(kernel, n_classes=3)
        outs = [np.asarray(get_backend(n).fitness(op, arg, Xn, y, consts, spec, fs))
                for n in ("scalar", "jnp", "pallas")]
        assert np.isinf(outs[0]).any(), f"{kernel}: NaN point never poisoned a tree"
        for o in outs[1:]:
            np.testing.assert_array_equal(np.isinf(o), np.isinf(outs[0]))


# --- fitness-kernel registry -------------------------------------------------


def test_kernel_registry_contents():
    assert {"r", "c", "m", "mse", "pearson", "r2"} <= set(available_kernels())
    assert fit.get_kernel("regression") is fit.get_kernel("r")  # alias
    with pytest.raises(ValueError, match="unknown fitness kernel"):
        fit.get_kernel("nope")
    with pytest.raises(ValueError, match="already registered"):
        register_kernel(FitnessKernel(name="r", partial_fitness=None, metric=None))


def test_two_pass_protocol_normalization():
    """register_kernel fills in the derivable half of the protocol:
    decomposable kernels get a derived M=1 moment pass; moment-defined
    kernels get a derived whole-dataset partial_fitness; half-specified
    kernels are rejected."""
    r = fit.get_kernel("r")
    assert r.moments is not None and r.n_moments == 1
    preds = jnp.asarray([[1.0, 2.0], [0.0, 0.0]])
    y, w = jnp.asarray([1.0, 1.0]), jnp.asarray([1.0, 1.0])
    spec = FitnessSpec("r")
    m = r.moments(preds, y, w, spec)
    assert m.shape == (2, 1)
    np.testing.assert_array_equal(np.asarray(r.reduce_moments(m, spec)),
                                  np.asarray(r.partial_fitness(preds, y, w, spec)))
    # pearson/r2 define both halves explicitly: the centered exact
    # single-pass partial and the shardable raw-moment form must agree
    # on well-conditioned data
    for name in ("pearson", "r2"):
        k = fit.get_kernel(name)
        assert k.n_moments > 1 and not k.decomposable
        sp = FitnessSpec(name)
        np.testing.assert_allclose(
            np.asarray(k.partial_fitness(preds, y, w, sp)),
            np.asarray(k.reduce_moments(k.moments(preds, y, w, sp), sp)),
            rtol=1e-4, atol=1e-4)
    # a moment-only kernel gets its whole-dataset partial derived
    if "test-meanerr" not in available_kernels():
        register_kernel(FitnessKernel(
            name="test-meanerr", n_moments=2, metric=None,
            moments=lambda p, y, w, s: jnp.stack(
                [jnp.broadcast_to(w[None, :], p.shape).sum(-1),
                 (jnp.abs(jnp.nan_to_num(p) - y[None, :])
                  * w[None, :]).sum(-1)], axis=-1),
            reduce_moments=lambda m, s: m[..., 1] / jnp.maximum(m[..., 0], 1.0)))
    k = fit.get_kernel("test-meanerr")
    assert not k.decomposable and k.partial_fitness is not None
    np.testing.assert_allclose(
        np.asarray(k.partial_fitness(preds, y, w, FitnessSpec("test-meanerr"))),
        np.asarray(k.reduce_moments(
            k.moments(preds, y, w, FitnessSpec("test-meanerr")),
            FitnessSpec("test-meanerr"))))
    with pytest.raises(ValueError, match="reduce_moments"):
        register_kernel(FitnessKernel(name="test-half", metric=None,
                                      moments=lambda p, y, w, s: None))
    with pytest.raises(ValueError, match="partial_fitness or moments"):
        register_kernel(FitnessKernel(name="test-empty", metric=None))


def test_nan_never_wins_any_kernel():
    """round(NaN)→int is undefined; every built-in kernel must sanitize a
    NaN tree to worst fitness so it can never win a tournament. (Fixed
    list, not available_kernels(): other tests register demo kernels that
    make no NaN promise.)"""
    preds = jnp.asarray([[0.0, 1.0, 2.0], [jnp.nan, 1.0, 2.0]])
    y = jnp.asarray([0.0, 1.0, 2.0])
    for kernel in ("r", "c", "m", "mse", "pearson"):
        f = np.asarray(fit.fitness_from_preds(preds, y, FitnessSpec(kernel)))
        assert np.isinf(f[1]), f"{kernel}: NaN tree got fitness {f[1]}"
        assert f[0] < f[1], f"{kernel}: NaN tree would win a tournament"


def test_nan_on_padded_points_is_ignored():
    preds = jnp.asarray([[1.0, jnp.nan]])
    y = jnp.asarray([1.0, 0.0])
    w = jnp.asarray([1.0, 0.0])  # NaN only on the padded point
    for kernel in ("r", "c", "m", "mse"):
        f = np.asarray(fit.fitness_from_preds(preds, y, FitnessSpec(kernel), weight=w))
        assert np.isfinite(f[0]), f"{kernel}: padding NaN leaked into fitness"


def test_custom_kernel_plugs_into_engine():
    """A user kernel registers once and is reachable from selection code
    (evolve_step) without touching it — the registry's reason to exist."""
    name = "test-hinge"
    if name not in available_kernels():
        register_kernel(FitnessKernel(
            name=name,
            partial_fitness=lambda p, y, w, spec: (
                jnp.where(w[None, :] > 0, jnp.maximum(0.0, 1.0 - p * y[None, :]), 0.0)
                .sum(-1)),
            metric=lambda p, y, spec: jnp.maximum(0.0, 1.0 - p * y[None, :]).mean(-1)))
    X_rows, y, _ = kepler()
    sess = GPSession(pop_size=16, generations=2, kernel=name, backend="jnp")
    sess.fit(X_rows, y)
    assert np.isfinite(sess.best_fitness)
    assert len(sess.history) == 2


def test_two_pass_kernels_accepted_on_mesh():
    """pearson/r2 moments psum across the data axis — the old
    'not sum-decomposable' rejection is gone. Only a kernel registered
    with NO moment pass at all (legacy full-data objective) stays
    single-device, with a clear error."""
    from repro.core.engine import GPConfig, sharded_evolve_step
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(data=1, model=1)
    for kernel in ("pearson", "r2"):
        step, specs = sharded_evolve_step(
            GPConfig(pop_size=8, fitness=FitnessSpec(kernel)), mesh)
        assert callable(step)

    name = "test-legacy-full"
    if name not in available_kernels():
        register_kernel(FitnessKernel(
            name=name, decomposable=False,
            partial_fitness=lambda p, y, w, spec: jnp.zeros(p.shape[0]),
            metric=lambda p, y, spec: jnp.zeros(p.shape[0])))
    assert fit.get_kernel(name).moments is None
    with pytest.raises(ValueError, match="moment pass"):
        sharded_evolve_step(GPConfig(pop_size=8, fitness=FitnessSpec(name)), mesh)


def test_correlation_kernels_degenerate_trees():
    """Two failure modes the moment form must not mismeasure: a
    CONSTANT-prediction tree (zero variance — cancellation noise must
    not crown it r²=1/perfect) and a tree with an inf prediction at a
    valid point (must be +inf fitness, never NaN — NaN wins argmin)."""
    rng = np.random.RandomState(0)
    y = jnp.asarray((5 + rng.randn(512)).astype(np.float32))
    const = jnp.full((1, 512), 3.0)
    good = y[None, :] * 1.001
    k = fit.get_kernel("pearson")
    spec = FitnessSpec("pearson")
    # moments merged across 4 simulated shards (the kernel's Chan
    # combine — centered moments are NOT plain-summable), then reduced
    parts = [k.moments(jnp.concatenate([const, good])[:, i * 128:(i + 1) * 128],
                       y[i * 128:(i + 1) * 128], jnp.ones(128), spec)
             for i in range(4)]
    m = fit.fold_moment_partials(k, parts, spec)
    f = np.asarray(k.reduce_moments(m, spec))
    assert f[0] > 0.99, f"constant tree scored as correlated: {f[0]}"
    assert f[1] < 0.01, f"near-perfect tree mis-scored: {f[1]}"

    inf_preds = y[None, :] * jnp.asarray(
        np.where(np.arange(512) == 7, np.inf, 1.0), jnp.float32)
    for name in ("pearson", "r2"):
        s = FitnessSpec(name)
        kk = fit.get_kernel(name)
        for f in (fit.fitness_from_preds(inf_preds, y, s),
                  kk.reduce_moments(fit.moments_from_preds(inf_preds, y, s), s)):
            f = np.asarray(f)
            assert np.isposinf(f).all(), f"{name}: inf pred gave {f}, not +inf"


def test_r2_kernel_end_to_end():
    """The kernel registered purely through moments/reduce_moments drives
    a whole single-device run — registry, engine, selection, score."""
    X_rows, y, _ = kepler()
    s = GPSession(pop_size=24, generations=4, kernel="r2", backend="jnp")
    s.fit(X_rows, y, key=jax.random.PRNGKey(0))
    assert np.isfinite(s.best_fitness) and s.best_fitness >= 0.0
    assert len(s.history) == 4
    assert s.score(X_rows, y) <= 1.0  # metric is R² (1 = perfect)


# --- GPSession front door ----------------------------------------------------


def test_session_backend_switch_is_one_string():
    """The acceptance bar: switching backends requires no other change."""
    X_rows, y, _ = iris()
    results = {}
    for backend in ("jnp", "pallas"):
        s = GPSession(pop_size=40, generations=4, max_depth=4, kernel="c",
                      n_classes=3, backend=backend)
        s.fit(X_rows, y, key=jax.random.PRNGKey(5))
        results[backend] = s.best_fitness
    assert results["jnp"] == pytest.approx(results["pallas"], abs=1e-3)


def test_session_scalar_backend_same_door():
    X_rows, y, _ = kepler()
    s = GPSession(pop_size=12, generations=2, kernel="r", backend="scalar")
    s.fit(X_rows, y)
    assert s.generation == 2 and len(s.history) == 2
    assert np.isfinite(s.best_fitness)


def test_session_results_api():
    X_rows, y, _ = kepler()
    s = GPSession(pop_size=60, generations=8, kernel="r",
                  feature_names=["r"])
    s.fit(X_rows, y, key=jax.random.PRNGKey(0))
    expr = s.best_expression()
    assert "r" in expr or expr.replace(".", "").replace("-", "").isdigit()
    preds = s.predict(X_rows)
    assert preds.shape == y.shape
    assert s.score(X_rows, y) >= 0.0  # mean |err|
    # warm start continues instead of resetting
    g0 = s.generation
    s.fit(X_rows, y, generations=2, warm_start=True)
    assert s.generation == g0 + 2


def test_session_rejects_feature_mismatch():
    X_rows, y, _ = iris()
    s = GPSession(pop_size=8, generations=1, n_features=2)
    with pytest.raises(ValueError, match="n_features"):
        s.ingest(X_rows, y)


def test_session_rejects_scalar_topology():
    with pytest.raises(ValueError, match="host-only"):
        GPSession(backend="scalar", topology=MeshTopology(data=1))


def test_core_run_forwards_with_deprecation():
    from repro.core import FitnessSpec as FS
    from repro.core import GPConfig, TreeSpec, run

    X_rows, y, _ = kepler()
    X = np.ascontiguousarray(X_rows.T)
    cfg = GPConfig(pop_size=30, generations=3,
                   tree_spec=TreeSpec(max_depth=4, n_features=1),
                   fitness=FS("r"))
    with pytest.warns(DeprecationWarning, match="GPSession"):
        state = run(cfg, X, y, key=jax.random.PRNGKey(7))
    sess = GPSession(cfg)
    sess.ingest(X, y, layout="features")
    sess.init(key=jax.random.PRNGKey(7))
    sess.evolve()
    assert float(state.best_fitness) == float(sess.state.best_fitness)


def test_estimators_sklearn_protocol():
    X_rows, y, _ = kepler()
    reg = SymbolicRegressor(pop_size=60, generations=8,
                            fn_set=("add", "sub", "mul", "div", "sqrt", "square"))
    reg.fit(X_rows, y)
    assert reg.score(X_rows, y) > 0.5
    assert isinstance(reg.expression_, str)
    Xc, yc, _ = iris()
    clf = SymbolicClassifier(n_classes=3, pop_size=40, generations=4)
    clf.fit(Xc, yc)
    labels = clf.predict(Xc)
    assert set(np.unique(labels)) <= {0, 1, 2}
    assert clf.score(Xc, yc) > 1 / 3


# --- topology (multi-device → subprocess) ------------------------------------

_SUBPROCESS_TOPOLOGY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.gp import GPSession, MeshTopology

    rng = np.random.RandomState(1)
    X_rows = np.abs(rng.randn(128, 2)).astype(np.float32) + 0.5
    y = (X_rows[:, 0] ** 2 / X_rows[:, 1]).astype(np.float32)

    # island (pod) topology — same fit() call as single-device
    s = GPSession(pop_size=64, generations=12, kernel="r", migrate_every=3,
                  topology=MeshTopology(data=2, model=2, pod=2))
    s.fit(X_rows, y)
    assert np.isfinite(s.best_fitness), s.best_fitness
    assert s.generation == 12, s.generation
    assert len(s.best_expression()) > 0

    # flat 2D mesh
    s2 = GPSession(pop_size=64, generations=6, kernel="r",
                   topology=MeshTopology(data=4, model=2))
    s2.fit(X_rows, y)
    assert np.isfinite(s2.best_fitness)

    # indivisible rows shard via zero-weight padding instead of raising
    s3 = GPSession(pop_size=64, generations=4, kernel="r",
                   topology=MeshTopology(data=4, model=2))
    s3.fit(X_rows[:126], y[:126])
    assert s3.n_rows == 126, s3.n_rows  # real rows, not the padded 128
    assert np.isfinite(s3.best_fitness)
    print("TOPOLOGY_OK")
""")


@pytest.mark.tier2
def test_session_topology_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROCESS_TOPOLOGY], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "TOPOLOGY_OK" in r.stdout
