"""Vectorized evaluator vs paper-faithful scalar baseline + no-NaN property."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import primitives as prim
from repro.core.eval import evaluate_population
from repro.core.scalar_eval import evaluate_population_scalar, fitness_scalar
from repro.core.fitness import FitnessSpec, fitness_from_preds
from repro.core.trees import TreeSpec, generate_population


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), depth=st.integers(1, 5),
       nf=st.integers(1, 6), rows=st.sampled_from([1, 17, 64]))
def test_vector_matches_scalar(seed, depth, nf, rows):
    spec = TreeSpec(max_depth=depth, n_features=nf, n_consts=4,
                    fn_set=prim.KITCHEN_SINK)
    op, arg = generate_population(jax.random.PRNGKey(seed), 12, spec)
    X = np.random.RandomState(seed % 1000).randn(nf, rows).astype(np.float32)
    got = np.asarray(evaluate_population(op, arg, jnp.asarray(X),
                                         spec.const_table(), spec))
    want = evaluate_population_scalar(op, arg, X.T, np.asarray(spec.const_table()))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_fitness_never_nan_on_extremes(seed):
    """Division/log/sqrt are protected, and overflow-born NaN (inf - inf)
    is sanitized to +inf at the fitness layer — a tournament can never
    select on NaN, on any data including zeros and f32 extremes."""
    spec = TreeSpec(max_depth=5, n_features=3, n_consts=4,
                    fn_set=prim.KITCHEN_SINK)
    op, arg = generate_population(jax.random.PRNGKey(seed), 32, spec)
    X = np.array([[0.0, 1e-30, -1e30], [0.0, -0.0, 1e30], [1.0, 0.0, -1.0]],
                 np.float32).T.reshape(3, 3)
    preds = evaluate_population(op, arg, jnp.asarray(X), spec.const_table(), spec)
    fit = np.asarray(fitness_from_preds(preds, jnp.zeros((3,)), FitnessSpec("r")))
    assert not np.isnan(fit).any()
    fit_c = np.asarray(fitness_from_preds(preds, jnp.zeros((3,)),
                                          FitnessSpec("c", n_classes=2)))
    assert not np.isnan(fit_c).any()


def test_fitness_kernels_match_scalar():
    spec = TreeSpec(max_depth=4, n_features=3, n_consts=4)
    op, arg = generate_population(jax.random.PRNGKey(3), 10, spec)
    X = np.random.RandomState(0).randn(3, 50).astype(np.float32)
    y = (np.random.RandomState(1).rand(50) * 3).astype(np.float32)
    preds = evaluate_population(op, arg, jnp.asarray(X), spec.const_table(), spec)
    for kern in ("r", "c", "m"):
        fs = FitnessSpec(kern, n_classes=3, precision=0.5)
        got = np.asarray(fitness_from_preds(preds, jnp.asarray(y), fs))
        want = fitness_scalar(op, arg, X.T, y, np.asarray(spec.const_table()),
                              kernel=kern, n_classes=3, precision=0.5)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)
