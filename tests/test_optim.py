"""Optimizers + gradient compression properties."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.optim.adamw import adamw, adafactor, cosine_schedule
from repro.optim.compress import dequantize, quantize


@pytest.mark.parametrize("make", [adamw, adafactor])
def test_optimizer_descends_quadratic(make):
    opt = make(lr=0.1)
    params = {"w": jnp.asarray(np.random.RandomState(0).randn(8, 4).astype(np.float32)),
              "b": jnp.asarray(np.random.RandomState(1).randn(4).astype(np.float32))}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    l0 = float(loss(params))
    for step in range(50):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, step)
    assert float(loss(params)) < 0.05 * l0


def test_adafactor_state_is_factored():
    opt = adafactor()
    params = {"w": jnp.zeros((64, 32)), "v": jnp.zeros((16,))}
    st_ = opt.init(params)
    assert st_["stats"]["w"]["r"].shape == (64,)
    assert st_["stats"]["w"]["c"].shape == (32,)
    assert st_["stats"]["v"]["v"].shape == (16,)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr(100)) == pytest.approx(0.0, abs=1e-9)
    assert float(lr(55)) < 1e-3


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-6, 1e4))
def test_quantize_roundtrip_error_bound(seed, scale):
    x = np.random.RandomState(seed).randn(64).astype(np.float32) * scale
    q, s = quantize(jnp.asarray(x))
    back = np.asarray(dequantize(q, s))
    assert np.abs(back - x).max() <= float(s) * 0.5 + 1e-12


_COMPRESS_SUBPROCESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.optim.compress import compressed_psum
    from repro.launch.mesh import make_host_mesh
    from repro import compat

    mesh = make_host_mesh(data=4, model=1)
    rng = np.random.RandomState(0)
    gs = rng.randn(4, 128).astype(np.float32)

    def body(g, r):
        mean, new_r = compressed_psum({"g": g}, "data", {"g": r})
        return mean["g"], new_r["g"]

    f = jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(P("data"), P("data")),
                                  out_specs=(P("data"), P("data"))))
    with compat.set_mesh(mesh):
        resid = jnp.zeros((4*128 // 4 * 4,), jnp.float32).reshape(512)[:512]*0
        resid = jnp.zeros((512,), jnp.float32)
        g = jnp.asarray(gs.reshape(512))
        mean, resid = f(g, resid)
    true_mean = gs.reshape(4, 128).mean(0)
    got = np.asarray(mean).reshape(4, 128)[0]
    # shared-scale quantization: error of the mean bounded by scale/2
    err = np.abs(got - true_mean).max()
    assert err < np.abs(gs).max() / 127 * 0.75 + 1e-6, err
    # error feedback: residual holds what was lost
    assert np.isfinite(np.asarray(resid)).all()
    print("COMPRESS_OK")
""")


@pytest.mark.tier2
def test_compressed_psum_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _COMPRESS_SUBPROCESS], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "COMPRESS_OK" in r.stdout


def test_error_feedback_converges():
    """EF-compressed SGD must track uncompressed SGD on a quadratic."""
    w = jnp.ones((32,)) * 5.0
    w_ref = jnp.ones((32,)) * 5.0
    resid = jnp.zeros((32,))
    for _ in range(200):
        g = 2 * w
        g_fb = g + resid
        q, s = quantize(g_fb)
        g_hat = dequantize(q, s)
        resid = g_fb - g_hat
        w = w - 0.01 * g_hat
        w_ref = w_ref - 0.01 * (2 * w_ref)
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref), atol=0.05)
