"""Sharding rules: every param/cache/batch spec must be legal (divisible)
on both production meshes, for every assigned architecture — this is the
cheap non-compiling half of the dry-run contract."""
import subprocess
import sys
import os
import textwrap

import pytest


_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax
    import numpy as np
    from repro.configs import all_arch_names, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch import sharding as SH
    from repro.launch.dryrun import make_policy
    from repro.models import model as Md
    from repro.optim.adamw import for_config

    for multi in (False, True):
        mesh = make_production_mesh(multi_pod=multi)
        sizes = {n: s for n, s in zip(mesh.axis_names, mesh.devices.shape)}
        for name in all_arch_names():
            cfg = get_config(name).with_policy(make_policy(mesh))
            opt = for_config(cfg)
            def init(key):
                p = Md.init_params(cfg, key)
                return {"params": p, "opt": opt.init(p), "step": 0}
            shapes = jax.eval_shape(init, jax.random.PRNGKey(0))
            specs = SH.train_state_specs(cfg, shapes, mesh)
            def check(path, sds, spec):
                for dim, ax in zip(sds.shape, spec):
                    if ax is None: continue
                    axes = ax if isinstance(ax, tuple) else (ax,)
                    n = int(np.prod([sizes[a] for a in axes]))
                    assert dim % n == 0, (name, multi, path, sds.shape, spec)
            jax.tree_util.tree_map_with_path(
                lambda p, s, sp: check(p, s, sp), shapes, specs)
            # decode cache specs
            for shape in ("decode_32k", "long_500k"):
                if not Md.shape_supported(cfg, shape): continue
                kind, sp = Md.input_specs(cfg, shape)
                cs = SH.cache_specs(cfg, sp["cache"], mesh,
                                    seq_shard=Md.SHAPES[shape]["batch"] == 1)
                jax.tree_util.tree_map_with_path(
                    lambda p, s, q: check(p, s, q), sp["cache"], cs)
    print("SPECS_OK")
""")


@pytest.mark.tier2
def test_all_specs_legal_on_production_meshes():
    env = dict(os.environ, PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, (r.stderr[-3000:] or r.stdout[-2000:])
    assert "SPECS_OK" in r.stdout


def test_param_count_sanity():
    """Config param counts must land near the published sizes."""
    from repro.configs import get_config
    expected = {  # billions, generous tolerance (published counts vary
        # with embedding/tying conventions)
        "qwen1.5-32b": (32, 0.15),
        "gemma-2b": (2.5, 0.25),
        "mistral-large-123b": (123, 0.10),
        "minitron-8b": (8.3, 0.20),
        "qwen3-moe-30b-a3b": (30.5, 0.15),
        "jamba-1.5-large-398b": (398, 0.15),
        "llama-3.2-vision-90b": (88, 0.20),
        "mamba2-370m": (0.37, 0.25),
        "whisper-medium": (0.76, 0.4),
        "granite-moe-3b-a800m": (3.3, 0.3),
    }
    for name, (target, tol) in expected.items():
        n = get_config(name).param_count() / 1e9
        assert abs(n - target) / target < tol, (name, n, target)


def test_active_param_counts_moe():
    from repro.configs import get_config
    a3b = get_config("qwen3-moe-30b-a3b").active_param_count() / 1e9
    assert 2.0 < a3b < 4.5, a3b
    j94 = get_config("jamba-1.5-large-398b").active_param_count() / 1e9
    assert 75 < j94 < 110, j94
