"""Fault-tolerance runtime: restart policy, straggler detection, heartbeats."""
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.runtime.fault import HeartbeatMonitor, StepMonitor, run_with_restarts


def test_restart_resumes_from_checkpoint():
    """Inject failures at fixed steps; the run must complete with state
    identical to a failure-free run (checkpoint/restart correctness)."""
    fails = {7: True, 13: True}

    def make_state():
        return {"x": jnp.zeros((), jnp.float32)}

    def step_fn_factory(fail_plan):
        def step(state, i):
            if fail_plan.pop(i, False):
                raise RuntimeError(f"injected node failure at {i}")
            return {"x": state["x"] + 1.0}
        return step

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3, every=5)
        state, restarts = run_with_restarts(make_state, step_fn_factory(dict(fails)),
                                            20, mgr, max_restarts=5)
    assert restarts == 2
    assert float(state["x"]) == 20.0


def test_restart_gives_up_after_max():
    def step(state, i):
        raise RuntimeError("always down")

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, every=100)
        try:
            run_with_restarts(lambda: {"x": jnp.zeros(())}, step, 5, mgr,
                              max_restarts=2)
            raised = False
        except RuntimeError:
            raised = True
    assert raised


def test_straggler_detection():
    mon = StepMonitor(threshold=3.0, alpha=0.5)
    for _ in range(5):
        with mon:
            time.sleep(0.01)
    with mon:
        time.sleep(0.2)  # straggler step
    assert len(mon.stragglers) == 1
    assert mon.stragglers[0][0] == 5


def test_heartbeats():
    hb = HeartbeatMonitor(deadline_s=0.05)
    hb.beat("w0")
    hb.beat("w1")
    assert hb.dead_workers() == []
    time.sleep(0.08)
    hb.beat("w1")
    assert hb.dead_workers() == ["w0"]


def test_heartbeat_remove_forgets_worker():
    """A deliberately departed worker (an evicted service job) must not
    read as dead forever."""
    hb = HeartbeatMonitor(deadline_s=0.05)
    hb.beat("w0")
    hb.beat("w1")
    hb.remove("w0")
    time.sleep(0.08)
    hb.beat("w1")
    assert hb.dead_workers() == []
    hb.remove("never-seen")  # idempotent


def test_restart_until_predicate_stops_early():
    """`until=` ends the loop when the state satisfies the predicate —
    the service's drain-the-queue termination."""
    def step(state, i):
        return {"x": state["x"] + 1.0}

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, every=1)
        state, restarts = run_with_restarts(
            lambda: {"x": jnp.zeros((), jnp.float32)}, step, 50, mgr,
            until=lambda s: float(s["x"]) >= 3.0)
    assert restarts == 0
    assert float(state["x"]) == 3.0


def test_session_block_monitor_stats():
    """GPSession.evolve threads each block through a StepMonitor —
    stats must expose the wall-time EMA and the straggler list."""
    from repro.gp import GPSession

    r = np.random.RandomState(0)
    X = r.randn(16, 2).astype(np.float32)
    y = (X[:, 0] * X[:, 1]).astype(np.float32)
    sess = GPSession(pop_size=8, max_depth=3, kernel="r", generations=2,
                     backend="jnp")
    sess.fit(X, y)
    assert sess.stats["blocks"] >= 1
    assert sess.stats["block_s_ema"] is not None
    assert sess.stats["block_s_ema"] > 0.0
    assert isinstance(sess.stats["stragglers"], list)
