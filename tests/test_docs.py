"""Docs are part of the system: every relative Markdown link and anchor in
README, ROADMAP, and docs/ must resolve. Runs standalone (no repro import,
no network) so the CI `docs` job can execute exactly this file; external
http(s) links are out of scope by design — the check must never flake on
someone else's server."""
import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOC_FILES = sorted(
    p for p in [ROOT / "README.md", ROOT / "ROADMAP.md",
                *(ROOT / "docs").glob("*.md")] if p.exists())

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE = re.compile(r"^(```|~~~)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$")


def _strip_fences(text: str) -> str:
    """Drop fenced code blocks — `[idx](call)`-shaped code is not a link."""
    out, in_fence = [], False
    for line in text.splitlines():
        if _FENCE.match(line.strip()):
            in_fence = not in_fence
            continue
        if not in_fence:
            out.append(line)
    return "\n".join(out)


def _github_slug(heading: str) -> str:
    """GitHub's anchor slugger: inline code/emphasis markers dropped,
    lowercase, spaces to hyphens, punctuation removed."""
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def _anchors(path: pathlib.Path) -> set:
    return {_github_slug(m.group(1))
            for line in _strip_fences(path.read_text()).splitlines()
            if (m := _HEADING.match(line))}


def _links(path: pathlib.Path) -> list:
    return _LINK.findall(_strip_fences(path.read_text()))


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: str(p.relative_to(ROOT)))
def test_relative_links_and_anchors_resolve(doc):
    bad = []
    for link in _links(doc):
        if link.startswith(("http://", "https://", "mailto:")):
            continue  # external: out of scope, never flake on the network
        target, _, anchor = link.partition("#")
        target_path = (doc if not target
                       else (doc.parent / target).resolve())
        if not target_path.exists():
            bad.append(f"{link}: file {target!r} does not exist")
            continue
        if anchor and target_path.suffix == ".md":
            if anchor not in _anchors(target_path):
                bad.append(f"{link}: no heading slugs to {anchor!r} in "
                           f"{target_path.name}")
    assert not bad, f"{doc.name}: broken links:\n  " + "\n  ".join(bad)


def test_docs_exist_and_are_linked_from_readme():
    """The docs/ subsystem ships with the repo and is reachable from the
    front page (ISSUE 3 acceptance criterion)."""
    for name in ("architecture.md", "fitness-kernels.md",
                 "observability.md"):
        assert (ROOT / "docs" / name).exists(), f"docs/{name} missing"
    readme_links = _links(ROOT / "README.md")
    assert any("docs/architecture.md" in l for l in readme_links)
    assert any("docs/fitness-kernels.md" in l for l in readme_links)
    assert any("docs/observability.md" in l for l in readme_links)
