"""Context-parallel (seq-sharded-cache) decode attention == single-device
attn_decode, including the cache write landing on the owning shard."""
import os
import subprocess
import sys
import textwrap
import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_host_mesh
    from repro import compat
    from repro.launch.serving import cp_decode_attention
    from repro.models.layers import AttnDims, attn_decode, attn_init

    mesh = make_host_mesh(data=8, model=1)
    dims = AttnDims(d_model=32, n_heads=4, n_kv=2, d_head=8)
    p = attn_init(jax.random.PRNGKey(0), dims)
    rng = np.random.RandomState(0)
    B, S = 2, 64
    ck = jnp.asarray(rng.randn(B, S, 2, 8).astype(np.float32) * 0.3)
    cv = jnp.asarray(rng.randn(B, S, 2, 8).astype(np.float32) * 0.3)

    for cur_len in (0, 7, 13, 40, 63):
        x = jnp.asarray(rng.randn(B, 1, 32).astype(np.float32) * 0.3)
        want_o, want_k, want_v = attn_decode(p, x, ck, cv,
                                             jnp.asarray(cur_len), dims)
        with compat.set_mesh(mesh):
            got_o, got_k, got_v = jax.jit(
                lambda p, x, ck, cv, L: cp_decode_attention(
                    p, x, ck, cv, L, dims, mesh, seq_axis="data"))(
                p, x, ck, cv, jnp.asarray(cur_len, jnp.int32))
        np.testing.assert_allclose(np.asarray(got_o), np.asarray(want_o),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(got_k), np.asarray(want_k),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v),
                                   rtol=1e-6, atol=1e-6)
        ck, cv = got_k, got_v  # roll the cache forward
    print("CP_DECODE_OK")
""")


@pytest.mark.tier2
def test_cp_decode_matches_reference():
    env = dict(os.environ, PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "CP_DECODE_OK" in r.stdout
