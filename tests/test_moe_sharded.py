"""moe_apply_sharded (explicit EP via shard_map) vs the reference path —
subprocess tests (need 8 fake devices)."""
import os
import subprocess
import sys
import textwrap
import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.models import moe as M
    from repro.models.transformer import ShardingPolicy
    from repro.launch.mesh import make_host_mesh
    from repro import compat

    mesh = make_host_mesh(data=2, model=4)
    pol = ShardingPolicy(batch=("data",), model="model", tp_size=4, dp_size=2)
    rng = np.random.RandomState(0)
    d, ff, E, B, S = 16, 32, 8, 4, 8
    x = jnp.asarray(rng.randn(B, S, d).astype(np.float32) * 0.5)

    # divisible experts
    p = M.moe_init(jax.random.PRNGKey(0), d, ff, E)
    y_ref, _ = M.moe_apply(p, x, top_k=2, capacity_factor=8.0)
    with compat.set_mesh(mesh):
        y_sh, _ = jax.jit(lambda p, x: M.moe_apply_sharded(
            p, x, top_k=2, capacity_factor=8.0, policy=pol))(p, x)
    np.testing.assert_allclose(np.asarray(y_sh), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)

    # non-divisible experts (granite case): 5 -> padded to 8
    p5 = M.moe_init(jax.random.PRNGKey(1), d, ff, 5)
    y5_ref, _ = M.moe_apply(p5, x, top_k=2, capacity_factor=8.0)
    with compat.set_mesh(mesh):
        y5_sh, _ = jax.jit(lambda p, x: M.moe_apply_sharded(
            p, x, top_k=2, capacity_factor=8.0, policy=pol))(p5, x)
    np.testing.assert_allclose(np.asarray(y5_sh), np.asarray(y5_ref),
                               rtol=2e-5, atol=2e-5)

    # gradients through shard_map + all_to_all + remat
    with compat.set_mesh(mesh):
        g = jax.jit(jax.grad(lambda p, x: M.moe_apply_sharded(
            p, x, top_k=2, policy=pol)[0].astype(jnp.float32).sum()))(p, x)
    assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(g))
    print("MOE_SHARDED_OK")
""")


@pytest.mark.tier2
def test_moe_sharded_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MOE_SHARDED_OK" in r.stdout
