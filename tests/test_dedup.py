"""Population-wide subexpression dedup (docs/genomes.md).

Tier 1 (exact): span-math edge cases, signature injectivity, plan
reconstruction pinned BITWISE against the plain stack interpreter —
across eval impl × fitness kernel × island layout, through full evolve
trajectories, the tenant batch and the overflow fallback. Tier 2
(semantic): the probe-fingerprint elite-cache gate, tolerance-pinned.
The 8-device mesh trajectory pin lives in the tier2 subprocess test.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import FitnessSpec, GPConfig, evolve_step, init_state
from repro.core import engine as eng
from repro.core import eval as ce
from repro.core import primitives as prim
from repro.core import trees
from repro.core.islands import IslandConfig
from repro.core.trees import TreeSpec, generate_population, heap_to_postfix
from repro.kernels import ops as kops


def _pops(seed, pop=33, depth=5, nf=4):
    spec_t = TreeSpec(max_depth=depth, n_features=nf, n_consts=8)
    spec_p = dataclasses.replace(spec_t, genome="postfix")
    op_t, arg_t = generate_population(jax.random.PRNGKey(seed), pop, spec_t)
    op_p, arg_p = heap_to_postfix(op_t, arg_t)
    return spec_t, spec_p, (op_t, arg_t), (op_p, arg_p)


def _data(seed, nf, D):
    r = np.random.RandomState(seed)
    X = jnp.asarray(r.randn(nf, D).astype(np.float32))
    y = jnp.asarray((r.rand(D) * 3).astype(np.float32))
    return X, y


def _dup_heavy(seed, pop, depth, nf=4):
    """A duplicate-heavy postfix population: few distinct genomes, many
    copies — the regime the dedup tier exists for."""
    spec_t, spec_p, _, (op, arg) = _pops(seed, pop=max(4, pop // 8),
                                         depth=depth, nf=nf)
    reps = -(-pop // op.shape[0])
    op = jnp.tile(op, (reps, 1))[:pop]
    arg = jnp.tile(arg, (reps, 1))[:pop]
    return spec_p, op, arg


# --- span math edge cases (trees.subtree_spans & friends) --------------------


def test_spans_single_terminal_row():
    """One active CONST: its span starts at 0 and the running stack depth
    is 1 after it; EMPTY padding contributes +1 per slot by contract."""
    N = 7
    op = np.zeros((1, N), np.int32)
    op[0, 0] = prim.CONST
    S = np.asarray(trees.postfix_stack_depths(op))
    np.testing.assert_array_equal(S[0], np.arange(1, N + 1))
    start = np.asarray(trees.subtree_spans(op))
    assert start[0, 0] == 0


def test_spans_full_length_row():
    """A caterpillar chain filling every slot of N=7: t t + t + t + .
    Binary spans telescope back to 0; each lhs index is the previous
    chain result; the row finishes with stack depth exactly 1."""
    add = prim.opcode_of("add")
    op = np.asarray([[prim.CONST, prim.CONST, add, prim.FEATURE, add,
                      prim.FEATURE, add]], np.int32)
    S = np.asarray(trees.postfix_stack_depths(op))
    np.testing.assert_array_equal(S[0], [1, 2, 1, 2, 1, 2, 1])
    start = np.asarray(trees.subtree_spans(op))
    np.testing.assert_array_equal(start[0], [0, 1, 0, 3, 0, 5, 0])
    lhs = np.asarray(trees.postfix_lhs_index(op))
    assert lhs[0, 2] == 0 and lhs[0, 4] == 2 and lhs[0, 6] == 4


def test_spans_all_padding_row():
    """All-EMPTY rows must stay well-defined (they exist in real
    populations: the tenant batch's empty slots): every EMPTY bumps the
    depth, so each position's 'span' is just itself."""
    N = 15
    op = np.zeros((3, N), np.int32)
    S = np.asarray(trees.postfix_stack_depths(op))
    np.testing.assert_array_equal(S, np.tile(np.arange(1, N + 1), (3, 1)))
    start = np.asarray(trees.subtree_spans(op))
    np.testing.assert_array_equal(start, np.tile(np.arange(N), (3, 1)))
    lhs = np.asarray(trees.postfix_lhs_index(op))
    assert (lhs >= -1).all()


# --- signature canonicalization ----------------------------------------------


def _brute_tokens(op, arg, K):
    """Reference canonical form: the token tuple of the subexpression
    ending at each active position (what the packed signature encodes)."""
    op, arg = np.asarray(op), np.asarray(arg)
    start = np.asarray(trees.subtree_spans(op))
    out = {}
    for p in range(op.shape[0]):
        for i in range(op.shape[1]):
            if op[p, i] == prim.EMPTY:
                continue
            toks = []
            for t in range(start[p, i], i + 1):
                o = int(op[p, t])
                a = int(np.clip(arg[p, t], 0, K - 1)) if prim.ARITY[o] == 0 else 0
                toks.append(1 + o * K + a)
            out[(p, i)] = tuple(toks)
    return out


def test_signatures_injective_on_population():
    """Equal packed signature ⟺ equal canonical token stream, checked
    against a brute-force per-span extraction on a real population."""
    _, spec_p, _, (op, arg) = _pops(23, pop=24, depth=4)
    sig = np.asarray(trees.subtree_signatures(op, arg, spec_p))
    K = max(spec_p.n_features, len(spec_p.const_table()), 1)
    toks = _brute_tokens(op, arg, K)
    by_sig, by_tok = {}, {}
    for (p, i), t in toks.items():
        by_sig.setdefault(tuple(sig[p, i]), set()).add(t)
        by_tok.setdefault(t, set()).add(tuple(sig[p, i]))
    assert all(len(v) == 1 for v in by_sig.values()), "signature collision"
    assert all(len(v) == 1 for v in by_tok.values()), "signature instability"


def test_signatures_inactive_positions_are_zero():
    _, spec_p, _, (op, arg) = _pops(29, pop=8, depth=3)
    sig = np.asarray(trees.subtree_signatures(op, arg, spec_p))
    inactive = np.asarray(op) == prim.EMPTY
    assert (sig[inactive] == 0).all()
    # ...and no ACTIVE subexpression packs to all-zero (word 0 carries a
    # token code >= 1), so padding can never alias a real subtree
    assert (sig[~inactive] != 0).any(axis=-1).all()


def test_signature_geometry_rejects_overwide_codes():
    with pytest.raises(ValueError):
        trees.signature_geometry(
            TreeSpec(max_depth=3, n_features=1 << 28, genome="postfix"), 15)


# --- plan + unique-subtree evaluation: bitwise reconstruction ----------------


def test_dedup_reconstruction_bitwise():
    spec_p, op, arg = _dup_heavy(3, pop=48, depth=5)
    X, _ = _data(3, 4, 200)
    ct = spec_p.const_table()
    base = np.asarray(ce.evaluate_population_postfix(op, arg, X, ct, spec_p))
    cap = op.shape[0] * op.shape[1] + 1  # roomy: the dedup path, not fallback
    out = np.asarray(ce.evaluate_population_dedup(op, arg, X, ct, spec_p, cap))
    np.testing.assert_array_equal(base, out)
    plan = ce.build_dedup_plan(op, arg, spec_p, cap)
    assert not bool(plan.overflow)
    assert int(plan.n_unique) < int(plan.total)  # duplicates actually deduped


def test_dedup_overflow_falls_back_bitwise():
    _, spec_p, _, (op, arg) = _pops(31, pop=40, depth=5)
    X, _ = _data(31, 4, 128)
    ct = spec_p.const_table()
    plan = ce.build_dedup_plan(op, arg, spec_p, 8)
    assert bool(plan.overflow)
    base = np.asarray(ce.evaluate_population_postfix(op, arg, X, ct, spec_p))
    out = np.asarray(ce.evaluate_population_dedup(op, arg, X, ct, spec_p, 8))
    np.testing.assert_array_equal(base, out)


def test_dedup_all_empty_rows_evaluate_to_zero():
    spec_p = TreeSpec(max_depth=4, n_features=3, n_consts=8, genome="postfix")
    N = spec_p.num_nodes
    op = jnp.zeros((5, N), jnp.int32)
    arg = jnp.zeros((5, N), jnp.int32)
    X, _ = _data(1, 3, 64)
    out = np.asarray(ce.evaluate_population_dedup(
        op, arg, X, spec_p.const_table(), spec_p, 64))
    np.testing.assert_array_equal(out, np.zeros((5, 64), np.float32))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), depth=st.integers(1, 5),
       pop=st.sampled_from([1, 9, 40]), cap=st.sampled_from([0, 8, 4096]))
def test_dedup_scatter_reconstruction_property(seed, depth, pop, cap):
    """For ANY population/cap: scatter-back of the unique-subtree table
    (or the overflow fallback) == the plain stack interpreter, bitwise."""
    _, spec_p, _, (op, arg) = _pops(seed % 10_000, pop=pop, depth=depth)
    X, _ = _data(seed % 97, 4, 96)
    ct = spec_p.const_table()
    cap = ce.resolve_dedup_cap(cap, pop, spec_p.num_nodes)
    base = np.asarray(ce.evaluate_population_postfix(op, arg, X, ct, spec_p))
    out = np.asarray(ce.evaluate_population_dedup(op, arg, X, ct, spec_p, cap))
    np.testing.assert_array_equal(base, out)


def test_resolve_dedup_cap():
    assert ce.resolve_dedup_cap(512, 1024, 63) == 512
    assert ce.resolve_dedup_cap(0, 1024, 63) == 1024
    assert ce.resolve_dedup_cap(0, 16, 63) == 64
    # never exceeds the total span count + the reserved empty-row slot
    assert ce.resolve_dedup_cap(10**9, 4, 7) == 4 * 7 + 1


def test_dedup_stats_matches_brute_force():
    spec_p, op, arg = _dup_heavy(17, pop=32, depth=4)
    K = max(spec_p.n_features, len(spec_p.const_table()), 1)
    toks = _brute_tokens(op, arg, K)
    uniq_ref = len(set(toks.values()))
    total_ref = len(toks)
    n_unique, saved = ce.dedup_stats(op, arg, spec_p, 100_000)
    assert int(n_unique) == uniq_ref
    assert int(saved) == total_ref - uniq_ref
    # overflowing cap zeroes `saved` (the eval path fell back) but still
    # reports the true distinct count — that's the telemetry contract
    n2, s2 = ce.dedup_stats(op, arg, spec_p, 4)
    assert int(n2) == uniq_ref and int(s2) == 0


# --- kernel-path parity: backend × kernel × impl, bitwise --------------------


@pytest.mark.parametrize("kernel", ["r", "mse", "pearson", "r2"])
@pytest.mark.parametrize("impl", ["jnp", "pallas"])
@pytest.mark.parametrize("cap", [0, 100_000])
def test_fitness_dedup_parity_bitwise(kernel, impl, cap):
    """dedup="exact" must not change a single bit of kops.fitness —
    P=100/D=777 exercises pop-, data-tile and unique-table padding.
    cap=0 (auto) overflows on this random population and takes the
    fallback branch of the jitted cond; the roomy cap takes the
    unique-subtree gather kernel. Both must be bitwise."""
    _, spec_p, _, (op, arg) = _pops(7, pop=100, depth=5)
    X, y = _data(7, 4, 777)
    fs = FitnessSpec(kernel)
    ct = spec_p.const_table()
    kw = dict(impl=impl, gather="vmem", data_tile=512, pop_tile=8)
    f0 = np.asarray(kops.fitness(op, arg, X, y, ct, spec_p, fs, **kw))
    f1 = np.asarray(kops.fitness(op, arg, X, y, ct, spec_p, fs,
                                 dedup="exact", dedup_cap=cap, **kw))
    np.testing.assert_array_equal(f0, f1)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_backend_fitness_dedup_parity_bitwise(backend):
    from repro.gp import get_backend

    _, spec_p, _, (op, arg) = _pops(5, pop=24, depth=4)
    X, y = _data(5, 4, 150)
    ct = spec_p.const_table()
    fs = FitnessSpec("r")
    b = get_backend(backend)
    f0 = np.asarray(b.fitness(op, arg, X, y, ct, spec_p, fs))
    f1 = np.asarray(b.fitness(op, arg, X, y, ct, spec_p, fs, dedup="exact"))
    np.testing.assert_array_equal(f0, f1)


def test_stream_moments_dedup_parity_bitwise():
    """The streaming fold builds ONE plan per call and shares it across
    chunks — merged moments must stay bitwise equal to dedup-off."""
    _, spec_p, _, (op, arg) = _pops(9, pop=32, depth=4)
    X, y = _data(9, 4, 600)
    ct = spec_p.const_table()
    from repro.core.fitness import get_kernel

    fs = FitnessSpec("pearson")
    acc = jnp.zeros((32, get_kernel("pearson").n_moments), jnp.float32)
    kw = dict(impl="jnp", data_tile=256)
    m0 = kops.stream_moments(acc, op, arg, X, y, ct, spec_p, fs, **kw)
    m1 = kops.stream_moments(acc, op, arg, X, y, ct, spec_p, fs,
                             dedup="exact", **kw)
    np.testing.assert_array_equal(np.asarray(m0), np.asarray(m1))


def test_pick_tiles_postfix_accounts_dedup_scratch():
    """Satellite: with the f32[U, Db] unique-subtree scratch live, the
    VMEM re-check must shrink the data tile before it overflows; with
    dedup_rows=0 the pick is unchanged from the seed behavior."""
    base = kops.pick_tiles_postfix(4, 6, 1024, 1 << 20, pop_tile=8,
                                   data_tile=65536)
    again = kops.pick_tiles_postfix(4, 6, 1024, 1 << 20, pop_tile=8,
                                    data_tile=65536, dedup_rows=0)
    assert base == again
    pt, dt, gather = kops.pick_tiles_postfix(4, 6, 1024, 1 << 20, pop_tile=8,
                                             data_tile=65536,
                                             dedup_rows=100_000)
    assert dt < base[1]  # the scratch is charged against the budget
    vmem = 4 * (4 * dt + pt * (6 + 8) * dt + 100_000 * dt)
    assert vmem <= kops._VMEM_BUDGET or dt == 128  # floor tile is the stop


# --- full-trajectory pins: evolve, islands, tenant batch ---------------------


@pytest.mark.parametrize("islands", [1, 3])
@pytest.mark.parametrize("cap", [0, 100_000])
def test_evolve_trajectory_dedup_bitwise(islands, cap):
    """dedup="exact" must not change a single bit of the evolution
    trajectory vs dedup="off" — auto cap (overflow fallback in play for
    random populations) and a roomy explicit cap (dedup path in play),
    classic and island layouts."""
    spec = TreeSpec(max_depth=4, n_features=3, n_consts=8, genome="postfix")
    X, y = _data(13, 3, 160)
    base = dict(pop_size=24, tree_spec=spec, fitness=FitnessSpec("r"),
                elitism=2, eval_impl="jnp", dedup_cap=cap,
                island=IslandConfig(islands=islands, migrate_every=2,
                                    migrate_k=2))
    c_off = GPConfig(dedup="off", **base)
    c_on = GPConfig(dedup="exact", **base)
    s_off = init_state(c_off, jax.random.PRNGKey(1))
    s_on = init_state(c_on, jax.random.PRNGKey(1))
    for g in range(6):
        s_off = evolve_step(c_off, s_off, X, y)
        s_on = evolve_step(c_on, s_on, X, y)
        for f in ("op", "arg", "fitness", "best_fitness", "best_op"):
            np.testing.assert_array_equal(
                np.asarray(getattr(s_off, f)), np.asarray(getattr(s_on, f)),
                err_msg=f"islands={islands} cap={cap} gen={g} field={f}")


def test_tenant_block_dedup_bitwise():
    """The multi-tenant batch: a dedup="exact" block must replay the
    dedup="off" block bit for bit (per-slot plans, frozen slots, the
    elite cache and the 7-column counter stream all in play)."""
    spec = TreeSpec(max_depth=4, n_features=3, n_consts=8, genome="postfix")
    I, P, Dc = 3, 16, 64
    state = eng.empty_tenant_state(I, P, spec, elitism=1)
    for i in range(I):
        sub = eng.init_tenant_slot(jax.random.PRNGKey(i), P, spec, elitism=1)
        state = jax.tree.map(lambda b, s, i=i: b.at[i].set(s), state, sub)
    r = np.random.RandomState(3)
    X = jnp.asarray(r.randn(I, 3, Dc).astype(np.float32))
    y = jnp.asarray(r.randn(I, Dc).astype(np.float32))
    w = jnp.ones((I, Dc), jnp.float32)
    params = eng.TenantParams(
        probs=jnp.tile(jnp.asarray([[0.1, 0.1, 0.1, 0.7]], jnp.float32),
                       (I, 1)),
        tourn=jnp.full((I,), 4, jnp.int32),
        point_rate=jnp.full((I,), 0.1, jnp.float32),
        kernel_id=jnp.zeros((I,), jnp.int32),
        n_classes=jnp.full((I,), 3.0, jnp.float32),
        precision=jnp.full((I,), 1e-4, jnp.float32),
        stop=jnp.full((I,), -jnp.inf, jnp.float32),
        budget=jnp.full((I,), 6, jnp.int32))
    blk_off = jax.jit(eng.build_tenant_block(spec, ("r",), 6, 1, 4))
    blk_on = jax.jit(eng.build_tenant_block(spec, ("r",), 6, 1, 4,
                                            dedup="exact", dedup_cap=100_000))
    st_off, h_off, c_off = blk_off(state, X, y, w, params)
    st_on, h_on, c_on = blk_on(state, X, y, w, params)
    for name, a, b in zip(st_off._fields, jax.tree.leaves(st_off),
                          jax.tree.leaves(st_on)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)
    np.testing.assert_array_equal(np.asarray(h_off), np.asarray(h_on))
    assert np.asarray(c_on).shape == np.asarray(c_off).shape
    assert np.asarray(c_on).shape[1] == 7


def test_counter_stream_reports_dedup_columns():
    """Duplicate-heavy population + roomy cap: the telemetry stream's
    SUBTREE_EVALS_SAVED / UNIQUE_SUBTREES columns go positive, and both
    stay zero with dedup="off"."""
    from repro.obs import counters as tc

    spec = TreeSpec(max_depth=4, n_features=3, n_consts=8, genome="postfix")
    X, y = _data(5, 3, 128)
    base = dict(pop_size=32, tree_spec=spec, fitness=FitnessSpec("r"),
                elitism=2, eval_impl="jnp")
    cfg = GPConfig(dedup="exact", dedup_cap=100_000, **base)
    _, _, ctr = eng.evolve_block(cfg, init_state(cfg, jax.random.PRNGKey(0)),
                                 X, y, None, n_steps=4)
    ctr = np.asarray(ctr)
    assert ctr.shape == (4, tc.N_COUNTERS) == (4, 7)
    assert (ctr[:, tc.UNIQUE_SUBTREES] > 0).all()
    # 32 trees over 3 features + 8 consts: pigeonhole guarantees shared
    # terminal subtrees every generation
    assert (ctr[:, tc.SUBTREE_EVALS_SAVED] > 0).all()
    cfg_off = GPConfig(dedup="off", **base)
    _, _, c0 = eng.evolve_block(cfg_off,
                                init_state(cfg_off, jax.random.PRNGKey(0)),
                                X, y, None, n_steps=4)
    assert (np.asarray(c0)[:, tc.SUBTREE_EVALS_SAVED:] == 0).all()


# --- tier 2: semantic probe-fingerprint cache --------------------------------


def _commute_adds(op, arg):
    """Swap the operands of every top-level add whose operands are both
    terminals: semantically identical (IEEE f32 addition is commutative),
    syntactically different — the recurring-but-rewritten elite."""
    add = prim.opcode_of("add")
    op, arg = np.asarray(op).copy(), np.asarray(arg).copy()
    for p in range(op.shape[0]):
        for i in range(2, op.shape[1]):
            if (op[p, i] == add and prim.ARITY[op[p, i - 1]] == 0
                    and prim.ARITY[op[p, i - 2]] == 0):
                op[p, i - 2], op[p, i - 1] = op[p, i - 1], op[p, i - 2]
                arg[p, i - 2], arg[p, i - 1] = arg[p, i - 1], arg[p, i - 2]
                break
    return jnp.asarray(op), jnp.asarray(arg)


def test_semantic_hit_serves_rewritten_elites():
    """A head row that is a commuted rewrite of the cached elite misses
    the exact gate but hits the semantic one; the served fitness is the
    cached value, which equals re-evaluation to f32 tolerance (here
    exactly, since commuted addition is bitwise)."""
    spec = TreeSpec(max_depth=4, n_features=3, n_consts=8, genome="postfix")
    cfg = GPConfig(pop_size=16, tree_spec=spec, fitness=FitnessSpec("r"),
                   elitism=2, eval_impl="jnp", dedup="semantic")
    X, y = _data(21, 3, 120)
    ct = spec.const_table()
    op_t, arg_t = generate_population(jax.random.PRNGKey(2), 16,
                                      dataclasses.replace(spec, genome="tree"))
    op, arg = heap_to_postfix(op_t, arg_t)
    op2, arg2 = _commute_adds(op[:2], arg[:2])
    changed = not (np.array_equal(np.asarray(op2), np.asarray(op[:2]))
                   and np.array_equal(np.asarray(arg2), np.asarray(arg[:2])))

    def eval_rows(o, a):
        return kops.fitness(o, a, X, y, ct, spec, FitnessSpec("r"), impl="jnp")

    full = np.asarray(eval_rows(op, arg))
    probe = eng._probe_fn(cfg, X, ct)
    assert probe is not None
    state = eng.GPState(
        key=jax.random.PRNGKey(0), op=op, arg=arg,
        fitness=jnp.full((16,), jnp.inf), best_op=op[0], best_arg=arg[0],
        best_fitness=jnp.asarray(jnp.inf), generation=jnp.asarray(0),
        cache_op=op2, cache_arg=arg2, cache_fit=jnp.asarray(full[:2]))
    served = np.asarray(eng._cached_fitness(state, eval_rows, probe=probe))
    np.testing.assert_allclose(served, full, rtol=1e-6, atol=1e-6)
    if changed:  # the hit really came through the semantic gate
        hit_exact = bool(jnp.all(state.op[:2] == state.cache_op)
                         & jnp.all(state.arg[:2] == state.cache_arg))
        assert not hit_exact


def test_semantic_zero_cache_never_hits():
    """The zero-initialized cache's all-EMPTY rows probe to 0.0 —
    exactly what a legitimate x-x elite produces. The all-finite guard
    on cache_fit keeps the +inf sentinel from being served to such a
    head even though the probe outputs match bitwise."""
    spec = TreeSpec(max_depth=4, n_features=3, n_consts=8, genome="postfix")
    cfg = GPConfig(pop_size=8, tree_spec=spec, fitness=FitnessSpec("r"),
                   elitism=2, eval_impl="jnp", dedup="semantic")
    X, y = _data(8, 3, 80)
    ct = spec.const_table()
    N = spec.num_nodes
    # population head: x0 - x0 rows — probe to 0.0 like the zero cache,
    # but differ from it in bytes, so only the semantic gate is in play
    sub = prim.opcode_of("sub")
    row_op = np.zeros((N,), np.int32)
    row_arg = np.zeros((N,), np.int32)
    row_op[:3] = [prim.FEATURE, prim.FEATURE, sub]
    op = jnp.asarray(np.tile(row_op, (8, 1)))
    arg = jnp.asarray(np.tile(row_arg, (8, 1)))
    state = init_state(cfg, jax.random.PRNGKey(0))._replace(op=op, arg=arg)
    assert np.isinf(np.asarray(state.cache_fit)).all()  # fresh sentinel
    probe = eng._probe_fn(cfg, X, ct)
    np.testing.assert_array_equal(  # the probe outputs DO match...
        np.asarray(probe(op[:2], arg[:2])),
        np.asarray(probe(state.cache_op, state.cache_arg)))

    def eval_rows(o, a):
        return kops.fitness(o, a, X, y, ct, spec, FitnessSpec("r"), impl="jnp")

    served = np.asarray(eng._cached_fitness(state, eval_rows, probe=probe))
    assert np.isfinite(served).all()  # ...but never the +inf sentinel


def test_semantic_trajectory_matches_off_within_tolerance():
    """dedup="semantic" trajectories stay within f32 tolerance of
    dedup="off" (the documented probe-collision contract — in practice
    random runs have no collisions and match bitwise)."""
    spec = TreeSpec(max_depth=4, n_features=3, n_consts=8, genome="postfix")
    X, y = _data(13, 3, 160)
    base = dict(pop_size=24, tree_spec=spec, fitness=FitnessSpec("r"),
                elitism=2, eval_impl="jnp")
    c_off = GPConfig(dedup="off", **base)
    c_sem = GPConfig(dedup="semantic", **base)
    s_off = init_state(c_off, jax.random.PRNGKey(1))
    s_sem = init_state(c_sem, jax.random.PRNGKey(1))
    for _ in range(6):
        s_off = evolve_step(c_off, s_off, X, y)
        s_sem = evolve_step(c_sem, s_sem, X, y)
        np.testing.assert_allclose(np.asarray(s_sem.fitness),
                                   np.asarray(s_off.fitness),
                                   rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(s_sem.best_fitness),
                               float(s_off.best_fitness),
                               rtol=1e-5, atol=1e-5)


def test_config_rejects_unknown_dedup():
    with pytest.raises(ValueError, match="dedup"):
        GPConfig(pop_size=8, tree_spec=TreeSpec(max_depth=3, n_features=2),
                 fitness=FitnessSpec("r"), dedup="fuzzy")


# --- 8-device mesh trajectory (tier2 subprocess) -----------------------------

_SUBPROCESS_MESH_DEDUP = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro import compat
    from repro.core import (GPConfig, TreeSpec, FitnessSpec, init_state,
                            sharded_evolve_block)
    from repro.core.islands import IslandConfig
    from repro.launch.mesh import make_host_mesh

    spec = TreeSpec(max_depth=4, n_features=2, n_consts=8, genome="postfix")
    rng = np.random.RandomState(1)
    X = jnp.asarray(np.abs(rng.randn(2, 128)).astype(np.float32) + 0.5)
    y = jnp.asarray((np.asarray(X)[0]**2 / np.asarray(X)[1]).astype(np.float32))
    w = jnp.ones((128,), jnp.float32)
    mesh = make_host_mesh(data=2, model=2, pod=2)

    for island in (None, IslandConfig(islands=2, migrate_every=2,
                                      migrate_k=2)):
        base = dict(pop_size=32, tree_spec=spec, fitness=FitnessSpec("r"))
        if island is not None:
            base["island"] = island
        outs = {}
        for mode in ("off", "exact"):
            cfg = GPConfig(dedup=mode, dedup_cap=100_000, **base)
            block, _ = sharded_evolve_block(cfg, mesh, n_steps=5,
                                            pod_axis="pod")
            with compat.set_mesh(mesh):
                s, hist, ctr = jax.jit(block)(
                    init_state(cfg, jax.random.PRNGKey(0)), X, y, w,
                    jnp.asarray(5, jnp.int32))
            outs[mode] = (s, np.asarray(hist))
        s0, h0 = outs["off"]; s1, h1 = outs["exact"]
        for name, a, b in zip(s0._fields, jax.tree.leaves(s0),
                              jax.tree.leaves(s1)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg="GPState." + name)
        np.testing.assert_array_equal(h0, h1)
    print("MESH_DEDUP_OK")
""")


@pytest.mark.tier2
def test_mesh_dedup_trajectory_subprocess():
    """dedup="exact" == dedup="off", bitwise, on an 8-device host mesh
    (per-shard plans over each shard's population slice), classic and
    island layouts."""
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROCESS_MESH_DEDUP], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MESH_DEDUP_OK" in r.stdout
