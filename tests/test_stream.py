"""Streaming chunked fitness — the chunking-invariance pins.

The contract under test (docs/fitness-kernels.md#streaming): evaluating a
dataset as a fold over fixed-shape zero-weight-padded chunks produces the
same fitness as one monolithic evaluation —

  * bitwise for decomposable kernels on integer-lattice data (all f32
    partial sums are exact integers, so summation order cannot matter),
  * ≤ 1e-4 relative for the Chan-combined kernels (pearson, r2),

across backends, ragged final chunks, all-padded chunks, chunk sizes
larger than the dataset, and (tier2) a mesh run that composes chunking
with the data-axis shard. Hypothesis property tests pin the algebra the
fold relies on: every registered kernel's merge is associative and has
the zero moment as identity, under random splits of random *fractionally
weighted* datasets (total weight < 1 included — the case the old
`maximum(n, 1)` mean guard silently broke).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.core import engine
from repro.core import fitness as fit
from repro.data.datasets import stream_rows
from repro.data.loader import ChunkedDataset
from repro.gp import GPSession


def _dataset(rows=500, feats=5, seed=0):
    rng = np.random.RandomState(seed)
    X = rng.randn(rows, feats).astype(np.float32)
    y = (X[:, 0] * X[:, 1] + X[:, 2]).astype(np.float32)
    return X, y


def _lattice(rows=96, feats=4, seed=0, classes=None):
    """Small-integer data: with fn_set +,-,* and p_const=0 every depth-3
    prediction and every decomposable moment is an exact f32 integer well
    under 2^24 — partial sums are order-independent, so chunked vs
    monolithic must agree BITWISE."""
    rng = np.random.RandomState(seed)
    X = rng.randint(-2, 3, size=(rows, feats)).astype(np.float32)
    if classes:
        y = rng.randint(0, classes, size=rows).astype(np.float32)
    else:
        y = rng.randint(-2, 3, size=rows).astype(np.float32)
    return X, y


def _pair(kernel, backend, X, y, chunk_rows, *, seed=1, **kw):
    """(monolithic fitness, streamed fitness) after one generation each,
    from identical init keys — so both evaluate the same population."""
    base = {"pop_size": 24, "max_depth": 4, "kernel": kernel,
            "backend": backend, **kw}
    sm = GPSession(**base)
    sm.ingest(X, y)
    sm.init(key=jax.random.PRNGKey(seed))
    sm.step()
    ss = GPSession(**base)
    ss.ingest(X, y, chunk_rows=chunk_rows)
    ss.init(key=jax.random.PRNGKey(seed))
    ss.step()
    return np.asarray(sm.state.fitness), np.asarray(ss.state.fitness)


# --- parity grid: backend x kernel (ragged final chunk throughout) -----------


GRID = ([("jnp", k) for k in ("mse", "c", "pearson", "r2")]
        + [("pallas", k) for k in ("mse", "r2")]
        + [("scalar", k) for k in ("mse", "pearson")])


@pytest.mark.parametrize("backend,kernel", GRID)
def test_stream_parity(backend, kernel):
    X, y = _dataset(rows=500)  # 500 % 128 != 0: ragged final chunk
    f_mono, f_stream = _pair(kernel, backend, X, y, 128)
    np.testing.assert_allclose(f_mono, f_stream, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("genome", ["tree", "postfix"])
def test_stream_parity_genomes(genome):
    X, y = _dataset(rows=300)
    f_mono, f_stream = _pair("mse", "jnp", X, y, 90, genome=genome)
    np.testing.assert_allclose(f_mono, f_stream, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("kernel", ["r", "c", "m"])
def test_stream_bitwise_decomposable(kernel):
    """Decomposable kernels on lattice data: chunked == monolithic, BITWISE,
    for both exact and ragged chunk boundaries."""
    X, y = _lattice(classes=3 if kernel == "c" else None)
    for chunk in (32, 40):  # 96 % 32 == 0; 96 % 40 != 0
        f_mono, f_stream = _pair(kernel, "jnp", X, y, chunk,
                                 fn_set="add,sub,mul", p_const=0.0, max_depth=3)
        np.testing.assert_array_equal(f_mono, f_stream)


def test_chunk_rows_larger_than_dataset():
    X, y = _dataset(rows=200)
    f_mono, f_stream = _pair("mse", "jnp", X, y, 4096)
    np.testing.assert_allclose(f_mono, f_stream, rtol=1e-5, atol=1e-6)


def test_session_chunking_invariance():
    """Two streamed runs with DIFFERENT chunk sizes produce identical
    evolution histories on lattice data (fitness bitwise => identical
    selection decisions)."""
    X, y = _lattice(rows=120)
    hist = []
    for chunk in (16, 64):
        s = GPSession(pop_size=24, max_depth=3, kernel="r", backend="jnp",
                      fn_set="add,sub,mul", p_const=0.0)
        s.ingest(X, y, chunk_rows=chunk)
        s.init(key=jax.random.PRNGKey(7))
        s.evolve(4)
        hist.append(list(s.history))
    assert hist[0] == hist[1]


def test_stream_islands():
    """Island-batched evolution composes with streaming (flattened [I*P]
    eval rides the same chunk fold)."""
    X, y = _dataset(rows=300)
    s = GPSession(pop_size=16, max_depth=3, kernel="mse", backend="jnp",
                  islands=3, migrate_every=2, migrate_k=2)
    s.ingest(X, y, chunk_rows=128)
    s.init(key=jax.random.PRNGKey(2))
    s.evolve(3)
    assert np.asarray(s.state.fitness).shape == (3, 16)
    assert np.isfinite(np.min(np.asarray(s.state.best_fitness)))


def test_stream_front_doors():
    """constructor chunk_rows=, ingest(stream=callable), and a prebuilt
    ChunkedDataset all route to the same fold."""
    X, y = _dataset(rows=256)
    s1 = GPSession(pop_size=16, max_depth=3, kernel="mse", backend="jnp",
                   chunk_rows=64)
    s1.ingest(X, y)
    s1.init(key=jax.random.PRNGKey(0))
    s1.step()

    def blocks():
        yield X, y

    s2 = GPSession(pop_size=16, max_depth=3, kernel="mse", backend="jnp")
    s2.ingest(stream=blocks, chunk_rows=64)
    s2.init(key=jax.random.PRNGKey(0))
    s2.step()
    s3 = GPSession(pop_size=16, max_depth=3, kernel="mse", backend="jnp")
    s3.ingest(stream=ChunkedDataset(X, y, chunk_rows=64))
    s3.init(key=jax.random.PRNGKey(0))
    s3.step()
    f1 = np.asarray(s1.state.fitness)
    np.testing.assert_allclose(f1, np.asarray(s2.state.fitness), rtol=1e-6)
    np.testing.assert_allclose(f1, np.asarray(s3.state.fitness), rtol=1e-6)
    with pytest.raises(ValueError, match="not both"):
        s3.ingest(X, y, stream=blocks)
    with pytest.raises(ValueError, match="chunk_rows"):
        GPSession(pop_size=16, backend="jnp").ingest(stream=blocks)


def test_stream_blocks_rejected():
    """Device-resident evolution blocks need a monolithic dataset — the
    streamed session must say so instead of failing downstream."""
    X, y = _dataset(rows=200)
    s = GPSession(pop_size=16, max_depth=3, kernel="mse", backend="jnp")
    s.ingest(X, y, chunk_rows=64)
    s.init(key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="chunk fold"):
        s.evolve_block(4)


# --- the fold algebra: merge identity + all-padded chunks --------------------


def _moments(kernel, preds, y, w):
    spec = fit.FitnessSpec(kernel=kernel)
    return fit.moments_from_preds(jnp.asarray(preds), jnp.asarray(y), spec,
                                  weight=jnp.asarray(w)), spec


@pytest.mark.parametrize("kernel", fit.available_kernels())
def test_all_padded_chunk_is_noop(kernel):
    """Folding an all-zero-weight (fully padded) chunk leaves the
    accumulator bitwise unchanged — the right-identity every streamed
    ragged tail relies on."""
    rng = np.random.RandomState(0)
    preds = rng.randn(4, 32).astype(np.float32)
    y = rng.randn(32).astype(np.float32)
    kern = fit.get_kernel(kernel)
    m, spec = _moments(kernel, preds, y, np.ones(32, np.float32))
    m_pad, _ = _moments(kernel, rng.randn(4, 32).astype(np.float32),
                        rng.randn(32).astype(np.float32),
                        np.zeros(32, np.float32))
    merged = kern.merge_moments(m, m_pad, spec)
    np.testing.assert_array_equal(np.asarray(merged), np.asarray(m))
    # and the zero accumulator itself is the fold's seed identity
    seeded = kern.merge_moments(jnp.zeros_like(m), m, spec)
    np.testing.assert_allclose(np.asarray(seeded), np.asarray(m),
                               rtol=1e-6, atol=1e-6)


def test_fractional_weight_mean_guard():
    """Total weight < 1 (fractional sample weights): the mean divisors
    must use the true Σw, not max(Σw, 1) — the merge of two half-weight
    shards must match the whole-dataset moments."""
    rng = np.random.RandomState(3)
    preds = rng.randn(3, 8).astype(np.float32)
    y = rng.randn(8).astype(np.float32)
    w = np.full(8, 0.06, np.float32)  # Σw = 0.48 < 1
    for kernel in ("pearson", "r2"):
        kern = fit.get_kernel(kernel)
        whole, spec = _moments(kernel, preds, y, w)
        m1, _ = _moments(kernel, preds[:, :5], y[:5], w[:5])
        m2, _ = _moments(kernel, preds[:, 5:], y[5:], w[5:])
        merged = kern.merge_moments(m1, m2, spec)
        np.testing.assert_allclose(np.asarray(merged), np.asarray(whole),
                                   rtol=1e-4, atol=1e-6, err_msg=kernel)


# --- hypothesis: merge associativity + chunking invariance, every kernel -----


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), rows=st.integers(4, 64),
       pop=st.integers(1, 4), n_cuts=st.integers(1, 4),
       fractional=st.booleans())
def test_merge_moments_properties(seed, rows, pop, n_cuts, fractional):
    rng = np.random.RandomState(seed)
    preds = (rng.randn(pop, rows) * 3).astype(np.float32)
    y = rng.randn(rows).astype(np.float32)
    w = rng.rand(rows).astype(np.float32)
    if fractional:
        w *= 0.9 / max(w.sum(), 1e-6)  # total weight < 1
    bounds = sorted({0, rows, *rng.randint(1, rows, size=n_cuts)})
    for kernel in fit.available_kernels():
        kern = fit.get_kernel(kernel)
        whole, spec = _moments(kernel, preds, y, w)
        parts = [_moments(kernel, preds[:, a:b], y[a:b], w[a:b])[0]
                 for a, b in zip(bounds, bounds[1:])]
        fold_l = parts[0]
        for p in parts[1:]:
            fold_l = kern.merge_moments(fold_l, p, spec)
        fold_r = parts[-1]
        for p in parts[-2::-1]:
            fold_r = kern.merge_moments(p, fold_r, spec)
        # associativity: both fold orders agree (to f32 noise) ...
        np.testing.assert_allclose(np.asarray(fold_l), np.asarray(fold_r),
                                   rtol=1e-3, atol=1e-4, err_msg=kernel)
        # ... and chunking is invariant on the REDUCED fitness
        f_whole = np.asarray(kern.reduce_moments(whole, spec))
        f_fold = np.asarray(kern.reduce_moments(fold_l, spec))
        np.testing.assert_allclose(f_fold, f_whole, rtol=1e-4, atol=1e-4,
                                   err_msg=kernel)
        # zero moment is a bitwise right identity
        z = jnp.zeros_like(fold_l)
        np.testing.assert_array_equal(
            np.asarray(kern.merge_moments(fold_l, z, spec)),
            np.asarray(fold_l), err_msg=kernel)
        # ... and a (1-ulp) left identity
        np.testing.assert_allclose(
            np.asarray(kern.merge_moments(z, fold_l, spec)),
            np.asarray(fold_l), rtol=1e-6, atol=1e-7, err_msg=kernel)


# --- engine-level fold + paper-scale generator -------------------------------


def test_chunked_fitness_matches_backend():
    """engine.chunked_fitness (the raw fold) == one monolithic backend
    call, for a prebuilt ChunkedDataset with sample weights."""
    from repro.gp import backends as B

    X, y = _dataset(rows=400)
    w = np.random.RandomState(5).rand(400).astype(np.float32)
    s = GPSession(pop_size=16, max_depth=4, kernel="r2", backend="jnp")
    s.ingest(X, y)
    s.init(key=jax.random.PRNGKey(4))
    op, arg = s.state.op, s.state.arg
    cfg = s._cfg
    mono = np.asarray(B.get_backend("jnp").fitness(
        np.asarray(op), np.asarray(arg), np.ascontiguousarray(X.T), y,
        np.asarray(cfg.tree_spec.const_table()), cfg.tree_spec, cfg.fitness,
        weight=w))
    ds = ChunkedDataset(X, y, chunk_rows=96, sample_weight=w)
    streamed = np.asarray(engine.chunked_fitness(cfg, op, arg, ds, impl="jnp"))
    np.testing.assert_allclose(mono, streamed, rtol=1e-4, atol=1e-4)


def test_stream_rows_blocking_invariant():
    """datasets.stream_rows yields THE SAME rows for any block size
    (sequential RandomState draws) — what lets the bench compare chunked
    against monolithic."""
    a = np.concatenate([b[0] for b in stream_rows(rows=1000, block_rows=170)()])
    b = np.concatenate([b[0] for b in stream_rows(rows=1000, block_rows=1000)()])
    np.testing.assert_array_equal(a, b)
    ya = np.concatenate([blk[1] for blk in stream_rows(rows=1000, block_rows=170)()])
    assert a.shape == (1000, 8) and ya.shape == (1000,)
    with pytest.raises(ValueError):
        stream_rows(rows=10, feats=2)


@pytest.mark.tier2
def test_stream_large_bounded_memory():
    """A 600k-row callable stream evolves with a peak device footprint of
    ONE chunk; n_rows is discovered during the first fold."""
    s = GPSession(pop_size=16, max_depth=3, kernel="mse", backend="jnp")
    s.ingest(stream=stream_rows(rows=600_000, block_rows=65_536),
             chunk_rows=131_072)
    s.init(key=jax.random.PRNGKey(0))
    s.evolve(2)
    assert s._n_rows == 600_000
    assert len(s.history) == 2 and np.isfinite(s.history[-1])


# --- mesh composition (tier2 subprocess: 8 host devices) ---------------------


_SUBPROCESS_MESH_STREAM = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.gp import GPSession, MeshTopology

    rng = np.random.RandomState(0)
    X = rng.randn(1000, 5).astype(np.float32)
    y = (X[:, 0] * X[:, 1] + X[:, 2]).astype(np.float32)
    for kernel in ("mse", "r2"):
        sm = GPSession(pop_size=32, max_depth=4, kernel=kernel,
                       topology=MeshTopology(data=4, model=2))
        sm.ingest(X, y, chunk_rows=300)  # 300 % 4 == 0; ragged tail too
        sm.init(key=jax.random.PRNGKey(3))
        sm.step()
        ss = GPSession(pop_size=32, max_depth=4, kernel=kernel, backend="jnp")
        ss.ingest(X, y)
        ss.init(key=jax.random.PRNGKey(3))
        ss.step()
        np.testing.assert_allclose(
            np.asarray(sm.state.fitness), np.asarray(ss.state.fitness),
            rtol=1e-4, atol=1e-4, err_msg=kernel)
    print("MESH_STREAM_OK")
""")


@pytest.mark.tier2
def test_mesh_stream_subprocess():
    """Chunking composes with the data-axis shard: a mesh streamed run
    matches the single-device monolithic fitness."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROCESS_MESH_STREAM], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MESH_STREAM_OK" in r.stdout


# --- ChunkedDataset unit behavior --------------------------------------------


def test_chunked_dataset_sources(tmp_path):
    X = np.arange(20, dtype=np.float32).reshape(10, 2)
    y = np.arange(10, dtype=np.float32)

    ds = ChunkedDataset(X, y, chunk_rows=4)
    chunks = list(ds)
    assert len(chunks) == 3 == ds.n_chunks and ds.n_rows == 10
    Xc, yc, wc = chunks[-1]
    assert Xc.shape == (2, 4) and wc.tolist() == [1, 1, 0, 0]
    # replayable: a second pass yields identical chunks
    again = list(ds)
    np.testing.assert_array_equal(chunks[0][0], again[0][0])

    # feature-major layout source
    ds_fm = ChunkedDataset(np.ascontiguousarray(X.T), y, chunk_rows=4,
                           layout="features")
    np.testing.assert_array_equal(list(ds_fm)[1][0], chunks[1][0])

    # one-shot iterator source: consumed once, cached for replay
    it = iter([(X[:6], y[:6]), (X[6:], y[6:])])
    ds_it = ChunkedDataset(it, chunk_rows=4)
    np.testing.assert_array_equal(list(ds_it)[2][1], chunks[2][1])
    np.testing.assert_array_equal(list(ds_it)[0][0], chunks[0][0])

    # memmapped .npy source streams from disk
    np.save(tmp_path / "x.npy", X)
    np.save(tmp_path / "y.npy", y)
    ds_np = ChunkedDataset.from_npy(tmp_path / "x.npy", tmp_path / "y.npy",
                                    chunk_rows=4)
    np.testing.assert_array_equal(list(ds_np)[0][0], chunks[0][0])


def test_chunked_dataset_weights_and_errors():
    X = np.ones((5, 3), np.float32)
    y = np.zeros(5, np.float32)
    w = np.arange(1, 6, dtype=np.float32)
    Xc, yc, wc = next(iter(ChunkedDataset(X, y, chunk_rows=8, sample_weight=w)))
    np.testing.assert_array_equal(wc, [1, 2, 3, 4, 5, 0, 0, 0])

    with pytest.raises(ValueError, match="chunk_rows"):
        ChunkedDataset(X, y, chunk_rows=0)
    with pytest.raises(ValueError, match="layout"):
        ChunkedDataset(X, y, chunk_rows=4, layout="cols")
    with pytest.raises(ValueError, match="need y"):
        ChunkedDataset(X, chunk_rows=4)
    with pytest.raises(ValueError, match="does not match"):
        ChunkedDataset(X, y[:3], chunk_rows=4)
    with pytest.raises(ValueError, match="weights"):
        ChunkedDataset(iter([(X, y, w[:5]), (X, y)]), chunk_rows=4)
    with pytest.raises(ValueError, match="inside the blocks"):
        ChunkedDataset(lambda: iter([(X, y)]), y, chunk_rows=4)


def test_chunked_dataset_empty():
    ds = ChunkedDataset(np.zeros((0, 3), np.float32),
                        np.zeros(0, np.float32), chunk_rows=8)
    chunks = list(ds)
    assert len(chunks) == 1 and ds.n_rows == 0
    Xc, yc, wc = chunks[0]
    assert Xc.shape == (3, 8) and wc.sum() == 0.0
