"""Optional-hypothesis shim.

Property tests use hypothesis when it is installed; in minimal
environments (no network, no wheel baked in) the decorated tests skip
individually instead of taking their whole module down at collection.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        return lambda fn: fn

    class _AnyStrategy:
        """Chainable stand-in so module-level strategy expressions parse."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()
