"""HLO cost analyzer: must match XLA on loop-free programs and correctly
multiply while-loop bodies by their trip counts (where XLA undercounts)."""
import os
import sys

import jax
import jax.numpy as jnp
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.hlo_cost import HloAnalyzer, analyze_hlo_text  # noqa: E402
from repro import compat  # noqa: E402


def _compile(f, *shapes):
    return jax.jit(f).lower(*shapes).compile()


def test_matches_xla_loop_free():
    def f(x, w):
        return jnp.tanh(x @ w) @ w.T

    c = _compile(f, jax.ShapeDtypeStruct((64, 128), jnp.float32),
                 jax.ShapeDtypeStruct((128, 128), jnp.float32))
    got = analyze_hlo_text(c.as_text())
    want = compat.cost_analysis(c)["flops"]
    assert abs(got["flops"] - want) / want < 0.05


def test_scan_multiplied_by_trip_count():
    def g(x):
        return jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=10)[0]

    c = _compile(g, jax.ShapeDtypeStruct((256, 256), jnp.float32))
    got = analyze_hlo_text(c.as_text())
    expect = 10 * 2 * 256**3
    assert abs(got["flops"] - expect) / expect < 0.05
    # and the built-in analysis indeed undercounts (the reason we exist)
    assert compat.cost_analysis(c)["flops"] < expect / 5


def test_nested_scans_compose():
    def body_inner(c, _):
        return c @ c, None

    def body_outer(c, _):
        c2, _ = jax.lax.scan(body_inner, c, None, length=3)
        return c2, None

    def f(x):
        return jax.lax.scan(body_outer, x, None, length=4)[0]

    c = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    got = analyze_hlo_text(c.as_text())
    expect = 4 * 3 * 2 * 128**3
    assert abs(got["flops"] - expect) / expect < 0.05


def test_computation_split_robust():
    def f(x):
        return jnp.sum(jax.nn.softmax(x @ x))

    c = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    a = HloAnalyzer(c.as_text())
    assert len(a.computations) >= 1
    cost = a.entry_cost()
    assert cost.flops >= 2 * 64**3
    assert cost.bytes > 0


def test_collectives_counted(tmp_path):
    text = """HloModule test

ENTRY %main.1 (p0: f32[16,128]) -> f32[16,128] {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ag = f32[32,128]{1,0} all-gather(%p0), replica_groups={}, dimensions={0}
  %slice = f32[16,128]{1,0} slice(%ag), slice={[0:16], [0:128]}
  ROOT %ar = f32[16,128]{1,0} all-reduce(%slice), to_apply=%add
}
"""
    got = analyze_hlo_text(text)
    assert got["collectives"]["all-gather"] == 32 * 128 * 4
    assert got["collectives"]["all-reduce"] == 16 * 128 * 4
