"""Quickstart: rediscover Kepler's 3rd law with the vectorized GP engine.

    PYTHONPATH=src python examples/quickstart.py

The engine evolves symbolic expressions over (orbital radius r) to predict
(orbital period p); the known answer is p = sqrt(r^3). Runs in seconds on
CPU — the same engine scales to a 512-chip mesh via launch/dryrun.py.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import GPConfig, TreeSpec, FitnessSpec, run
from repro.core import primitives as prim
from repro.core.trees import to_string
from repro.data.datasets import kepler
from repro.data.loader import feature_major


def main():
    X_rows, y, meta = kepler()
    spec = TreeSpec(max_depth=5, n_features=1, n_consts=8,
                    fn_set=prim.KITCHEN_SINK)
    cfg = GPConfig(name="kepler-quickstart", pop_size=200, tree_spec=spec,
                   fitness=FitnessSpec("r"), generations=30)
    state = run(cfg, feature_major(X_rows), y, key=jax.random.PRNGKey(0),
                callback=lambda g, s: g % 10 == 0 and print(
                    f"gen {g:2d}  best sum|err| = {float(s.best_fitness):.4f}"))
    tree = to_string(np.asarray(state.best_op), np.asarray(state.best_arg),
                     feature_names=["r"],
                     const_table=np.asarray(spec.const_table()))
    print(f"\nBest evolved law: p = {tree}")
    print(f"Residual: {float(state.best_fitness):.5f} (sum |err| over 9 planets)")


if __name__ == "__main__":
    main()
