"""Quickstart: rediscover Kepler's 3rd law with the vectorized GP engine.

    pip install -e .          # once, from the repo root
    python examples/quickstart.py

The engine evolves symbolic expressions over (orbital radius r) to predict
(orbital period p); the known answer is p = sqrt(r^3). Runs in seconds on
CPU — the same `GPSession` scales to a 512-chip mesh by adding
`topology=MeshTopology(data=..., model=..., pod=...)` (see launch/dryrun.py).
"""
import jax

from repro.gp import GPSession


def main():
    sess = GPSession.from_dataset(
        "kepler", name="kepler-quickstart", pop_size=200, generations=30,
        callback=lambda g, s: g % 10 == 0 and print(
            f"gen {g:2d}  best sum|err| = {float(s.best_fitness):.4f}"))
    sess.init(key=jax.random.PRNGKey(0))
    sess.evolve()
    print(f"\nBest evolved law: p = {sess.best_expression()}")
    print(f"Residual: {sess.best_fitness:.5f} (sum |err| over 9 planets)")
    print(f"Backend: {sess.backend} (auto-selected)")


if __name__ == "__main__":
    main()
