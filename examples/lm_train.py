"""Train a reduced-config LM from the assigned-architecture zoo, end to
end: sharded train step, checkpoint/resume, straggler monitor.

    python examples/lm_train.py --arch gemma-2b --steps 60

Any of the 10 assigned architectures works (--arch qwen3-moe-30b-a3b,
mamba2-370m, jamba-1.5-large-398b, ...); reduced configs keep it
CPU-friendly while exercising the exact production code path
(launch/train.py drives full configs on a real pod).
"""
import argparse
import dataclasses
import tempfile

from repro.configs import get_reduced
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()
    cfg = get_reduced(args.arch)
    if cfg.accum_steps > 1 and args.batch % cfg.accum_steps:
        cfg = dataclasses.replace(cfg, accum_steps=1)
    with tempfile.TemporaryDirectory() as ckpt:
        _, history, monitor = train(cfg, steps=args.steps, batch=args.batch,
                                    seq=args.seq, ckpt_dir=ckpt, ckpt_every=25)
    print(f"loss: {history[0]:.3f} -> {history[-1]:.3f} over {args.steps} steps")
    assert history[-1] < history[0], "loss should fall on the synthetic stream"


if __name__ == "__main__":
    main()
