"""Integration demo: GP symbolic search over LM activation statistics.

Composes both halves of the framework on one host: a reduced LM from the
assigned-architecture zoo produces per-position residual-stream statistics,
and the paper's GP engine evolves a symbolic expression over those
statistics that predicts the model's own per-token loss. (This is a demo
of the two subsystems sharing one mesh/runtime — not a claim from the
paper; DESIGN.md §5.)

    PYTHONPATH=src python examples/gp_feature_search.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data.loader import lm_batches
from repro.gp import GPSession
from repro.models import model as Md
from repro.models import transformer as T


def activation_features(cfg, params, batch):
    """Per-position features from the residual stream + per-token CE."""
    dt = jnp.float32
    p = Md._cast(params, dt)
    x = T.embed_tokens(cfg, p["tok"], batch["tokens"])
    x, _ = T.stack_apply_train(cfg, p["stack"], x, cfg.pattern)
    x = T._apply_norm(cfg, p["final_norm"], x)
    W = p["tok"]["embed"].T if cfg.tie_embeddings else p["tok"]["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", x, W)
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, batch["labels"][..., None], -1)[..., 0]
    nll = lse - gold  # [B, S]
    feats = jnp.stack([
        jnp.linalg.norm(x, axis=-1),          # residual norm
        x.mean(-1), x.std(-1),                # stream stats
        jnp.abs(x).max(-1),                   # peak activation
        lse,                                  # log partition
        logits.max(-1),                       # max logit
    ], axis=-1)  # [B, S, 6]
    return np.asarray(feats).reshape(-1, 6), np.asarray(nll).reshape(-1)


def main():
    cfg = get_reduced("gemma-2b")
    params = Md.init_params(cfg, jax.random.PRNGKey(0))
    batch = next(lm_batches(cfg.vocab, 8, 64, seed=1))
    X_rows, y = activation_features(cfg, params, batch)
    print(f"features: {X_rows.shape}, target: per-token NLL "
          f"(mean {y.mean():.3f})")

    names = ["norm", "mean", "std", "amax", "lse", "maxlogit"]
    sess = GPSession(name="feature-search", pop_size=120, generations=20,
                     max_depth=4, kernel="r", feature_names=names)
    sess.fit(X_rows, y, key=jax.random.PRNGKey(1))
    base = np.abs(y - y.mean()).sum()
    print(f"evolved loss-predictor: {sess.best_expression()}")
    print(f"sum|err| {sess.best_fitness:.2f} vs mean-baseline {base:.2f}")
    assert sess.best_fitness < base, "GP should beat the mean predictor"


if __name__ == "__main__":
    main()
