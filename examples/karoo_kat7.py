"""End-to-end driver: the paper's flagship workload — RFI classification on
the KAT-7-shaped dataset (10,000 × 9), full Table-2 configuration, 30
generations, per-generation archiving, wall-clock report.

    pip install -e .          # once, from the repo root
    python examples/karoo_kat7.py [--backend pallas] [--archive DIR]

This is the run that took 48 hours in scalar/SymPy form and ~3 minutes
vectorized in the paper (Fig. 3); `--backend scalar | jnp | pallas` walks
the same axis here, all through `repro.gp.GPSession`.
"""
import argparse

from repro.launch.evolve import run_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", "--impl", dest="backend", default="jnp",
                    help="eval backend: scalar | jnp | pallas | auto")
    ap.add_argument("--generations", type=int, default=30)
    ap.add_argument("--archive", default=None)
    args = ap.parse_args()
    state, wall, history = run_dataset(
        "kat7", generations=args.generations, pop=100, backend=args.backend,
        archive=args.archive)
    acc = -float(state.best_fitness) / 10_000
    print(f"wall: {wall:.1f}s for {args.generations} generations "
          f"({args.backend}); best accuracy {acc:.3f}")
    print("(paper: same configuration was 48 h scalar / ~197 s vectorized)")


if __name__ == "__main__":
    main()
