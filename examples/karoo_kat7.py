"""End-to-end driver: the paper's flagship workload — RFI classification on
the KAT-7-shaped dataset (10,000 × 9), full Table-2 configuration, 30
generations, per-generation archiving, wall-clock report.

    PYTHONPATH=src python examples/karoo_kat7.py [--impl pallas] [--archive DIR]

This is the run that took 48 hours in scalar/SymPy form and ~3 minutes
vectorized in the paper (Fig. 3); here both the vectorized XLA path and
the fused Pallas kernel path are available.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

from repro.launch.evolve import run_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--impl", default="jnp", choices=("jnp", "pallas"))
    ap.add_argument("--generations", type=int, default=30)
    ap.add_argument("--archive", default=None)
    args = ap.parse_args()
    state, wall, history = run_dataset(
        "kat7", generations=args.generations, pop=100, impl=args.impl,
        archive=args.archive)
    acc = -float(state.best_fitness) / 10_000
    print(f"wall: {wall:.1f}s for {args.generations} generations "
          f"({args.impl}); best accuracy {acc:.3f}")
    print("(paper: same configuration was 48 h scalar / ~197 s vectorized)")


if __name__ == "__main__":
    main()
