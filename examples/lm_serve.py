"""Serve a reduced LM: batched prefill + token-by-token decode with the
KV/SSM cache — the serve_step that the decode_32k/long_500k dry-run cells
lower at production scale.

    python examples/lm_serve.py --arch mamba2-370m --tokens 24
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models import model as Md


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params = Md.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    B, P = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (B, P)), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros((B, cfg.n_memory, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["memory"] = jnp.zeros((B, cfg.n_memory, cfg.d_model), jnp.bfloat16)

    max_len = P + args.tokens + 1
    t0 = time.perf_counter()
    logits, cache = Md.prefill(cfg, params, batch, max_len=max_len)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    decode = jax.jit(Md.make_serve_step(cfg))
    out = [np.asarray(tok)[:, 0]]
    for t in range(args.tokens - 1):
        logits, cache = decode(params, cache, tok, jnp.asarray(P + t, jnp.int32))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(np.asarray(tok)[:, 0])
    wall = time.perf_counter() - t0
    seqs = np.stack(out, 1)
    print(f"decoded {args.tokens} tokens x {B} seqs in {wall:.2f}s "
          f"({args.tokens*B/wall:.1f} tok/s incl. compile)")
    print("greedy continuations (token ids):")
    for row in seqs:
        print("  ", row[:12], "...")


if __name__ == "__main__":
    main()
