"""Fault tolerance runtime: heartbeats, straggler detection, auto-restart.

On a real multi-host deployment these hooks sit on top of
`jax.distributed` (one process per host): heartbeats go to a coordinator
(or a blob-store lease), a missed deadline marks the host failed, the
coordinator re-forms the job on the survivors, and every process restores
from the newest committed checkpoint (ckpt/) — resharding via
ckpt/elastic.py if the device count changed. This container is
single-process, so the monitors run against local threads and the restart
policy is exercised by tests/test_runtime.py via injected failures; the
control-flow is identical.
"""
from __future__ import annotations

import threading
import time
from typing import Callable


class StepMonitor:
    """Per-step wall-time EMA + straggler flagging.

    A step slower than `threshold × EMA` is recorded as a straggler event.
    At fleet scale the same signal (per-host step time skew) is what
    triggers hot-spare swap-in; here it feeds metrics and tests."""

    def __init__(self, threshold: float = 3.0, alpha: float = 0.2):
        self.threshold = threshold
        self.alpha = alpha
        self.ema: float | None = None
        self.last: float | None = None
        self.stragglers: list[tuple[int, float]] = []
        self.step = 0

    def __enter__(self):
        self._t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        dt = time.monotonic() - self._t0
        self.last = dt
        if self.ema is not None and dt > self.threshold * self.ema:
            self.stragglers.append((self.step, dt))
        self.ema = dt if self.ema is None else (1 - self.alpha) * self.ema + self.alpha * dt
        self.step += 1
        return False


class HeartbeatMonitor:
    """Liveness tracking for worker threads/processes. Workers call
    `beat(worker_id)`; `dead_workers()` returns anything silent past the
    deadline."""

    def __init__(self, deadline_s: float = 10.0):
        self.deadline_s = deadline_s
        self._last: dict[str, float] = {}
        self._lock = threading.Lock()

    def beat(self, worker_id: str):
        with self._lock:
            self._last[worker_id] = time.monotonic()

    def dead_workers(self) -> list[str]:
        now = time.monotonic()
        with self._lock:
            return [w for w, t in self._last.items() if now - t > self.deadline_s]

    def remove(self, worker_id: str):
        """Forget a worker that left ON PURPOSE (job evicted, host drained).
        Without this, a worker that stops beating because its job finished
        is indistinguishable from a dead one and `dead_workers()` reports
        it forever. Unknown ids are a no-op — eviction paths may race a
        worker that never got its first beat in."""
        with self._lock:
            self._last.pop(worker_id, None)


def run_with_restarts(make_state: Callable, step_fn: Callable, n_steps: int,
                      manager, *, max_restarts: int = 3, on_step=None,
                      until: Callable | None = None):
    """Restart-from-checkpoint execution policy.

    make_state() builds a fresh state; step_fn(state, i) -> state may raise
    (node failure). On failure we restore the newest committed checkpoint
    and continue; state identity is preserved across restarts.
    `until(state) -> bool`, when given, ends the run early once it reports
    the state finished — `n_steps` is then just a runaway bound (how
    drain-until-idle loops, e.g. the GP service scheduler, ride this
    policy without knowing their step count up front). Returns
    (state, restarts)."""
    restarts = 0
    state = make_state()
    restored, step0 = manager.restore_latest(like=state)
    i = int(step0) if restored is not None else 0
    if restored is not None:
        state = restored
    while i < n_steps and not (until is not None and until(state)):
        try:
            state = step_fn(state, i)
            i += 1
            manager.maybe_save(state, i)
            if on_step:
                on_step(i, state)
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            manager.wait()
            restored, step0 = manager.restore_latest(like=state)
            if restored is None:
                state, i = make_state(), 0
            else:
                state, i = restored, int(step0)
    manager.wait()
    return state, restarts
