"""Runtime fault tolerance: heartbeats, straggler detection, restart policy."""
from repro.runtime.fault import HeartbeatMonitor, StepMonitor, run_with_restarts  # noqa: F401
