"""The paper's four datasets (Table 3).

Kepler ships verbatim (9 planets, public). Iris/KAT-7/LIGO are generated
stand-ins with the exact assigned shapes: Iris as the classic 3-cluster
Gaussian mixture (real Iris class statistics), KAT-7 (10,000×9) and LIGO
glitch (4,000×1,373) as synthetic classification sets — both originals
are access-controlled (the paper itself notes the LIGO set is
LSC-members-only), and every figure in the paper measures *throughput*,
which depends only on shape. Labels are constructed from a nonlinear
feature rule so the classification kernels have real signal to find.
"""
from __future__ import annotations

import numpy as np

# Kepler's 3rd law: orbital radius r (AU) → period p (years); p = r^1.5.
# NASA planetary data (paper ref [4]); Pluto included, as the paper insists.
_KEPLER = np.array([
    # r (AU),  p (years)
    [0.387, 0.241],   # Mercury
    [0.723, 0.615],   # Venus
    [1.000, 1.000],   # Earth
    [1.524, 1.881],   # Mars
    [5.203, 11.862],  # Jupiter
    [9.539, 29.457],  # Saturn
    [19.18, 84.011],  # Uranus
    [30.06, 164.79],  # Neptune
    [39.53, 248.54],  # Pluto (forsaken)
], np.float32)


def kepler():
    """9×2 regression: X=[r] → y=p (GP must discover p = sqrt(r·r·r))."""
    return _KEPLER[:, :1], _KEPLER[:, 1], {"kernel": "r", "features": ["r"]}


# Classic Iris class statistics (Fisher 1936): per-class feature means/stds
# for (sepal_len, sepal_wid, petal_len, petal_wid).
_IRIS_MEANS = np.array([[5.01, 3.43, 1.46, 0.25],
                        [5.94, 2.77, 4.26, 1.33],
                        [6.59, 2.97, 5.55, 2.03]], np.float32)
_IRIS_STDS = np.array([[0.35, 0.38, 0.17, 0.11],
                       [0.52, 0.31, 0.47, 0.20],
                       [0.64, 0.32, 0.55, 0.27]], np.float32)


def iris(seed: int = 0):
    """150×4, 3 classes — Gaussian mixture at the real Iris statistics."""
    rng = np.random.RandomState(seed)
    X, y = [], []
    for c in range(3):
        X.append(rng.randn(50, 4).astype(np.float32) * _IRIS_STDS[c] + _IRIS_MEANS[c])
        y.append(np.full(50, c, np.float32))
    X, y = np.concatenate(X), np.concatenate(y)
    order = rng.permutation(150)
    return X[order], y[order], {"kernel": "c", "n_classes": 3}


def _synthetic_classification(rows: int, feats: int, seed: int, informative: int = 6):
    """Nonlinear binary labels over standard-normal features."""
    rng = np.random.RandomState(seed)
    X = rng.randn(rows, feats).astype(np.float32)
    w = rng.randn(informative).astype(np.float32)
    z = (X[:, :informative] * w).sum(-1) + 0.5 * X[:, 0] * X[:, 1] - 0.3 * np.abs(X[:, 2])
    y = (z > np.median(z)).astype(np.float32)
    return X, y


def kat7(rows: int = 10_000, seed: int = 1):
    """10,000×9 RFI-flagging stand-in (paper §3.5(3)): binary classification
    over per-channel statistics."""
    X, y = _synthetic_classification(rows, 9, seed)
    return X, y, {"kernel": "c", "n_classes": 2}


def ligo_glitch(rows: int = 4_000, feats: int = 1_373, seed: int = 2):
    """4,000×1,373 glitch-classification stand-in (paper §3.5(4)):
    2,000 one-glitch-type vs 2,000 all-others."""
    X, y = _synthetic_classification(rows, feats, seed, informative=24)
    return X, y, {"kernel": "c", "n_classes": 2}


def stream_rows(rows: int = 5_500_000, feats: int = 8, *, seed: int = 0,
                block_rows: int = 65_536):
    """Paper-scale synthetic regression stream: a CALLABLE yielding
    `(X [n, feats], y [n])` row blocks totalling `rows`, for the
    streaming-chunked-fitness path (`GPSession.ingest(stream=...)`).
    Nothing is ever materialized beyond one block.

    Deterministic for a given seed REGARDLESS of block_rows: blocks are
    drawn sequentially from one `np.random.RandomState`, whose state
    (gauss cache included) carries across block boundaries — so a
    chunked pass and a monolithic pass see the very same rows, which is
    what the chunking-invariance tests compare against."""
    if feats < 4:
        raise ValueError(f"stream_rows target uses features 0-3; feats={feats}")

    def blocks():
        rng = np.random.RandomState(seed)
        done = 0
        while done < rows:
            n = min(block_rows, rows - done)
            X = rng.randn(n, feats).astype(np.float32)
            y = (X[:, 0] * X[:, 1] + np.sin(X[:, 2])
                 - 0.5 * np.abs(X[:, 3])).astype(np.float32)
            yield X, y
            done += n

    return blocks


BY_NAME = {"kepler": kepler, "iris": iris, "kat7": kat7, "ligo": ligo_glitch}
