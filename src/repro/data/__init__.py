"""Datasets (paper's four + synthetic LM token streams) and sharded loaders."""
from repro.data.datasets import iris, kat7, kepler, ligo_glitch  # noqa: F401
from repro.data.loader import (  # noqa: F401
    feature_major, lm_batches, pad_feature_major, pad_rows, shard_dataset,
)
