"""Datasets (paper's four + synthetic LM token streams) and sharded loaders."""
from repro.data.datasets import iris, kat7, kepler, ligo_glitch  # noqa: F401
from repro.data.loader import feature_major, lm_batches, shard_dataset  # noqa: F401
