"""Sharded host-side data loading.

`feature_major` is the paper's Eq. 1 → Eq. 2 transposition: row-major
[rows, features] becomes feature-major [features, rows] so each feature is
a contiguous vector. `shard_dataset` pads rows to the data-axis tile and
places the arrays with their mesh sharding (zero-weight padding keeps
fitness exact). `lm_batches` is the synthetic token stream used by the
training driver and examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def feature_major(X_rows: np.ndarray) -> np.ndarray:
    return np.ascontiguousarray(X_rows.T)


def _check_multiple(multiple: int) -> int:
    if not isinstance(multiple, (int, np.integer)) or multiple <= 0:
        raise ValueError(f"pad multiple must be a positive integer, got "
                         f"{multiple!r}")
    return int(multiple)


def pad_rows(X_rows, y, multiple: int, *, weight=None):
    """Pad [rows, ...] data up to a multiple; returns (X, y, weight) where
    weight is 1.0 on real rows and 0.0 on padding — the mask the fitness
    kernels use to keep padded datasets scoring exactly. An explicit
    `weight` (f32[rows], e.g. sample weights) passes through on the real
    rows; padding rows always get 0.0."""
    multiple = _check_multiple(multiple)
    D = X_rows.shape[0]
    pad = (-D) % multiple
    if pad:
        X_rows = np.concatenate([X_rows, np.zeros((pad,) + X_rows.shape[1:], X_rows.dtype)])
        y = np.concatenate([y, np.zeros((pad,), y.dtype)])
    real_w = (np.ones(D, np.float32) if weight is None
              else np.asarray(weight, np.float32))
    w = np.concatenate([real_w, np.zeros(pad, np.float32)])
    return X_rows, y, w


def pad_feature_major(X_fm, y, multiple: int, *, weight=None):
    """`pad_rows` for already-transposed [features, rows] data: pads the
    trailing (data) axis. Returns (X [F, D'], y [D'], weight [D'])."""
    multiple = _check_multiple(multiple)
    F, D = X_fm.shape
    pad = (-D) % multiple
    if pad:
        X_fm = np.concatenate([X_fm, np.zeros((F, pad), X_fm.dtype)], axis=1)
        y = np.concatenate([y, np.zeros((pad,), y.dtype)])
    real_w = (np.ones(D, np.float32) if weight is None
              else np.asarray(weight, np.float32))
    w = np.concatenate([real_w, np.zeros(pad, np.float32)])
    return np.ascontiguousarray(X_fm), y, w


def shard_dataset(X_rows, y, mesh, data_axis: str = "data"):
    """→ (X [F, D'], y [D'], weight [D']) device-placed, D' padded to the
    data axis; weight is the padding mask (zero on padded columns)."""
    n = mesh.shape[data_axis]
    X_rows, y, w = pad_rows(np.asarray(X_rows, np.float32), np.asarray(y, np.float32), n)
    X = feature_major(X_rows)
    xs = jax.device_put(X, NamedSharding(mesh, P(None, data_axis)))
    ys = jax.device_put(y, NamedSharding(mesh, P(data_axis)))
    ws = jax.device_put(w, NamedSharding(mesh, P(data_axis)))
    return xs, ys, ws


class ChunkedDataset:
    """Fixed-shape chunk stream over a dataset of any size — the host side
    of streaming chunked fitness (docs/fitness-kernels.md#streaming).

    Iterating yields `(X_fm f32[F, chunk_rows], y f32[chunk_rows],
    weight f32[chunk_rows])` feature-major chunks. Every chunk — including
    the ragged final one — is zero-weight padded to the same fixed shape,
    so ONE compiled evaluation program serves the whole stream and a
    padded point contributes an exact 0.0 to every fitness moment.
    Iterate as many times as you like: evolution folds the stream once
    per generation.

    Sources (`source` positional):

      array     in-memory `[rows, features]` numpy array (`y` required);
                `np.load(path, mmap_mode="r")` memmaps work unchanged and
                stream from disk without ever materializing all rows
      callable  `source()` returns a FRESH iterator of `(X, y)` or
                `(X, y, weight)` row blocks (any block sizes — blocks are
                re-chunked to `chunk_rows`); re-invoked for every pass,
                so nothing is cached host-side
      iterator  a one-shot iterator/generator of the same blocks — it is
                consumed once at construction and the fixed-shape chunks
                cached host-side for replay

    `sample_weight` (array source only) scales each real point's fitness
    contribution and composes with the padding mask. `n_rows` is the REAL
    (pre-padding) row count — None for a callable source until its first
    full pass has been folded.
    """

    def __init__(self, source, y=None, *, chunk_rows: int, layout: str = "rows",
                 sample_weight=None, n_features: int | None = None):
        if not isinstance(chunk_rows, (int, np.integer)) or chunk_rows <= 0:
            raise ValueError(f"chunk_rows must be a positive integer, got "
                             f"{chunk_rows!r}")
        if layout not in ("rows", "features"):
            raise ValueError(f"layout must be 'rows' or 'features', got {layout!r}")
        self.chunk_rows = int(chunk_rows)
        self._layout = layout
        self._array = None  # [rows, F] or [F, rows] per layout (maybe memmap)
        self._y = None
        self._weight = None
        self._callable = None
        self._cache = None  # list of emitted chunks (one-shot iterator source)
        self._n_rows = None
        self.n_features = None  # set by the first block when not known up front

        if callable(source):
            self._callable = source
            if sample_weight is not None or y is not None:
                raise ValueError("callable sources yield (X, y[, weight]) "
                                 "blocks; pass weights inside the blocks")
            if n_features is None:
                # peek ONE block of a fresh iterator for F, then discard it
                first = next(iter(source()), None)
                if first is None:
                    raise ValueError("callable source yielded no blocks")
                n_features = np.asarray(first[0]).shape[-1]
            self.n_features = int(n_features)
        elif hasattr(source, "__next__") or (not hasattr(source, "shape")
                                             and hasattr(source, "__iter__")):
            if sample_weight is not None or y is not None:
                raise ValueError("iterator sources yield (X, y[, weight]) "
                                 "blocks; pass weights inside the blocks")
            self._cache = list(self._rechunk(source))
            if not self._cache:
                raise ValueError("iterator source yielded no blocks")
            self.n_features = int(self._cache[0][0].shape[0])
        else:
            X = np.asarray(source) if not isinstance(source, np.ndarray) else source
            if y is None:
                raise ValueError("array sources need y")
            y = np.asarray(y, np.float32)
            if X.ndim != 2:
                raise ValueError(f"array source must be 2-D, got shape {X.shape}")
            D = X.shape[0] if layout == "rows" else X.shape[1]
            if y.shape != (D,):
                raise ValueError(f"y shape {y.shape} does not match {D} data points")
            if sample_weight is not None:
                sample_weight = np.asarray(sample_weight, np.float32)
                if sample_weight.shape != (D,):
                    raise ValueError(f"sample_weight shape {sample_weight.shape} "
                                     f"does not match {D} data points")
            self._array, self._y, self._weight = X, y, sample_weight
            self._n_rows = D
            self.n_features = int(X.shape[1] if layout == "rows" else X.shape[0])

    @classmethod
    def from_npy(cls, x_path, y_path, *, chunk_rows: int, layout: str = "rows",
                 sample_weight=None) -> "ChunkedDataset":
        """Stream a dataset from `.npy` files via `np.load(mmap_mode="r")`
        — chunks are read from disk on demand, never the whole array."""
        return cls(np.load(x_path, mmap_mode="r"), np.load(y_path),
                   chunk_rows=chunk_rows, layout=layout,
                   sample_weight=sample_weight)

    @property
    def n_rows(self) -> int | None:
        """REAL (pre-padding) rows; None for a callable source that has
        not completed a pass yet."""
        return self._n_rows

    @property
    def n_chunks(self) -> int | None:
        if self._cache is not None:
            return len(self._cache)
        if self._n_rows is None:
            return None
        return max(1, -(-self._n_rows // self.chunk_rows))

    def _emit(self, X_rows, y, weight):
        """One fixed-shape chunk from ≤ chunk_rows real rows: transpose to
        feature-major f32 and zero-weight pad the tail."""
        n = y.shape[0]
        X_fm = np.ascontiguousarray(np.asarray(X_rows, np.float32).T)
        if self.n_features is None:
            self.n_features = int(X_fm.shape[0])
        if X_fm.shape[0] != self.n_features:
            raise ValueError(f"source block has {X_fm.shape[0]} features, "
                             f"expected {self.n_features}")
        w = (np.ones(n, np.float32) if weight is None
             else np.asarray(weight, np.float32))
        pad = self.chunk_rows - n
        if pad:
            X_fm = np.concatenate(
                [X_fm, np.zeros((X_fm.shape[0], pad), np.float32)], axis=1)
            y = np.concatenate([np.asarray(y, np.float32),
                                np.zeros(pad, np.float32)])
            w = np.concatenate([w, np.zeros(pad, np.float32)])
        return X_fm, np.ascontiguousarray(np.asarray(y, np.float32)), w

    def _rechunk(self, blocks):
        """Re-slice arbitrary (X, y[, weight]) row blocks into fixed
        `chunk_rows` chunks (row counting rides along)."""
        bx, by, bw, buffered, total = [], [], [], 0, 0
        any_weight = False

        def drain(final: bool):
            nonlocal bx, by, bw, buffered
            X = np.concatenate(bx) if len(bx) > 1 else bx[0]
            y = np.concatenate(by) if len(by) > 1 else by[0]
            w = (np.concatenate(bw) if len(bw) > 1 else bw[0]) if any_weight else None
            out = []
            stop = len(y) if final else (len(y) // self.chunk_rows) * self.chunk_rows
            for a in range(0, stop, self.chunk_rows):
                b = min(a + self.chunk_rows, stop)
                out.append(self._emit(X[a:b], y[a:b], None if w is None else w[a:b]))
            bx, by, bw = [X[stop:]], [y[stop:]], [] if w is None else [w[stop:]]
            buffered = len(y) - stop
            return out

        for block in blocks:
            X, y = np.asarray(block[0], np.float32), np.asarray(block[1], np.float32)
            if X.ndim != 2 or y.shape != (X.shape[0],):
                raise ValueError(f"source blocks must be (X [n, F], y [n][, "
                                 f"weight [n]]); got X {X.shape}, y {y.shape}")
            w = np.asarray(block[2], np.float32) if len(block) > 2 else None
            if bx and (w is not None) != any_weight:
                raise ValueError("source blocks must consistently include or "
                                 "omit weights")
            any_weight = w is not None
            bx.append(X)
            by.append(y)
            if any_weight:
                bw.append(w)
            buffered += len(y)
            total += len(y)
            if buffered >= self.chunk_rows:
                yield from drain(final=False)
        if buffered:
            yield from drain(final=True)
        self._n_rows = total

    def __iter__(self):
        if self._cache is not None:
            yield from self._cache
        elif self._callable is not None:
            yield from self._rechunk(self._callable())
        else:
            X, y, w, D = self._array, self._y, self._weight, self._n_rows
            for a in range(0, max(D, 1), self.chunk_rows):
                b = min(a + self.chunk_rows, D)
                if self._layout == "rows":
                    Xc = X[a:b]
                else:
                    Xc = np.asarray(X[:, a:b], np.float32).T
                yield self._emit(Xc, y[a:b], None if w is None else w[a:b])


def lm_batches(vocab: int, batch: int, seq: int, *, seed: int = 0, n_batches=None):
    """Deterministic synthetic token stream: a noisy order-k Markov chain so
    the loss actually falls during the example runs."""
    rng = np.random.RandomState(seed)
    table = rng.randint(0, vocab, size=(251,)).astype(np.int32)
    i = 0
    while n_batches is None or i < n_batches:
        noise = rng.randint(0, vocab, size=(batch, seq + 1), dtype=np.int32)
        base = (np.cumsum(noise % 7, axis=1) + i) % 251
        toks = np.where(rng.rand(batch, seq + 1) < 0.15, noise, table[base])
        yield {"tokens": jnp.asarray(toks[:, :-1]),
               "labels": jnp.asarray(toks[:, 1:]),
               "mask": jnp.ones((batch, seq), jnp.float32)}
        i += 1
