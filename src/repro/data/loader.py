"""Sharded host-side data loading.

`feature_major` is the paper's Eq. 1 → Eq. 2 transposition: row-major
[rows, features] becomes feature-major [features, rows] so each feature is
a contiguous vector. `shard_dataset` pads rows to the data-axis tile and
places the arrays with their mesh sharding (zero-weight padding keeps
fitness exact). `lm_batches` is the synthetic token stream used by the
training driver and examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def feature_major(X_rows: np.ndarray) -> np.ndarray:
    return np.ascontiguousarray(X_rows.T)


def pad_rows(X_rows, y, multiple: int):
    """Pad [rows, ...] data up to a multiple; returns (X, y, weight) where
    weight is 1.0 on real rows and 0.0 on padding — the mask the fitness
    kernels use to keep padded datasets scoring exactly."""
    D = X_rows.shape[0]
    pad = (-D) % multiple
    if pad:
        X_rows = np.concatenate([X_rows, np.zeros((pad,) + X_rows.shape[1:], X_rows.dtype)])
        y = np.concatenate([y, np.zeros((pad,), y.dtype)])
    w = np.concatenate([np.ones(D, np.float32), np.zeros(pad, np.float32)])
    return X_rows, y, w


def pad_feature_major(X_fm, y, multiple: int):
    """`pad_rows` for already-transposed [features, rows] data: pads the
    trailing (data) axis. Returns (X [F, D'], y [D'], weight [D'])."""
    F, D = X_fm.shape
    pad = (-D) % multiple
    if pad:
        X_fm = np.concatenate([X_fm, np.zeros((F, pad), X_fm.dtype)], axis=1)
        y = np.concatenate([y, np.zeros((pad,), y.dtype)])
    w = np.concatenate([np.ones(D, np.float32), np.zeros(pad, np.float32)])
    return np.ascontiguousarray(X_fm), y, w


def shard_dataset(X_rows, y, mesh, data_axis: str = "data"):
    """→ (X [F, D'], y [D'], weight [D']) device-placed, D' padded to the
    data axis; weight is the padding mask (zero on padded columns)."""
    n = mesh.shape[data_axis]
    X_rows, y, w = pad_rows(np.asarray(X_rows, np.float32), np.asarray(y, np.float32), n)
    X = feature_major(X_rows)
    xs = jax.device_put(X, NamedSharding(mesh, P(None, data_axis)))
    ys = jax.device_put(y, NamedSharding(mesh, P(data_axis)))
    ws = jax.device_put(w, NamedSharding(mesh, P(data_axis)))
    return xs, ys, ws


def lm_batches(vocab: int, batch: int, seq: int, *, seed: int = 0, n_batches=None):
    """Deterministic synthetic token stream: a noisy order-k Markov chain so
    the loss actually falls during the example runs."""
    rng = np.random.RandomState(seed)
    table = rng.randint(0, vocab, size=(251,)).astype(np.int32)
    i = 0
    while n_batches is None or i < n_batches:
        noise = rng.randint(0, vocab, size=(batch, seq + 1), dtype=np.int32)
        base = (np.cumsum(noise % 7, axis=1) + i) % 251
        toks = np.where(rng.rand(batch, seq + 1) < 0.15, noise, table[base])
        yield {"tokens": jnp.asarray(toks[:, :-1]),
               "labels": jnp.asarray(toks[:, 1:]),
               "mask": jnp.ones((batch, seq), jnp.float32)}
        i += 1
