"""repro.gp — the public GP API.

One front door (`GPSession`) over every run shape the paper spans —
scalar baseline, vectorized XLA, fused Pallas kernel, single device or
`MeshTopology(data=, model=, pod=)` island meshes — plus the two
registries that make the spectrum pluggable (`backends`, fitness kernels
in `repro.core.fitness`) and sklearn-style facades.
"""
from repro.core.engine import GPConfig, GPState  # noqa: F401
from repro.core.evolve import OperatorMix  # noqa: F401
from repro.core.islands import IslandConfig  # noqa: F401
from repro.core.fitness import (  # noqa: F401
    FitnessKernel, FitnessSpec, available_kernels, get_kernel, register_kernel,
)
from repro.gp.backends import (  # noqa: F401
    EvalBackend, auto_select, available_backends, get_backend, register_backend,
)
from repro.gp.estimators import SymbolicClassifier, SymbolicRegressor  # noqa: F401
from repro.gp.session import GPSession, MeshTopology, make_config  # noqa: F401
