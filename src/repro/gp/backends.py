"""EvalBackend registry — the paper's platform axis as pluggable objects.

The paper's result is one GP algorithm spanning six platforms by swapping
the evaluation configuration (scalar/SymPy vs. vector/TensorFlow, CPU vs.
GPU). Here each platform is an `EvalBackend` registered by name:

    scalar   the paper-faithful per-data-point interpreter (1-CPU_SP) —
             host-only, the baseline every speedup figure divides by
    jnp      vectorized XLA level-sweep (the paper's *-CPU_TF column)
    pallas   fused eval+fitness TPU kernel (GPU_TF / compiled-kernel
             column; interpret mode off-TPU)

Every backend exposes `evaluate(op, arg, X, const_table, tree_spec)` →
predictions and a fused `fitness(...)` → per-tree score, so the engine,
session, benchmarks and tests switch platforms with one string. New
execution strategies (e.g. a CUDA kernel, a sparse evaluator) register
here and are immediately reachable from `GPSession(backend=...)`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import numpy as np


@dataclasses.dataclass(frozen=True)
class EvalBackend:
    """One evaluation platform.

    evaluate: (op[P,N], arg[P,N], X[F,D], const_table[C], tree_spec) -> preds[P,D]
    fitness:  (op, arg, X, y, const_table, tree_spec, fit_spec,
               weight=None, data_tile=...) -> f32[P]
    moments:  same signature as fitness -> f32[P, M] — phase 1 of the
              two-pass fitness protocol (FitnessKernel.moments summed
              over this backend's tiles but NOT finalized). The mesh
              step `psum`s these across the data axis and applies
              `FitnessKernel.reduce_moments`; backends without a moment
              pass (None) cannot evaluate under a data-sharded mesh.

    stream_moments: (acc[P, M], op, arg, X, y, const_table, tree_spec,
              fit_spec, weight=None, data_tile=...) -> f32[P, M] — one
              streaming fold step: this chunk's phase-1 moments merged
              into the running accumulator via the kernel's merge. Seed
              with zeros (the merge identity), fold every fixed-shape
              chunk of a `data/loader.ChunkedDataset`, finalize once
              with `FitnessKernel.reduce_moments` — how a dataset larger
              than device memory evaluates in bounded memory. None means
              the backend cannot stream (fall back to `moments` + a host
              merge, or reject).

    `weight` is an optional f32[D] dataset-padding mask (0.0 on padded
    points) — every backend must score a padded dataset identically to
    the unpadded one. `jittable` backends run inside the engine's jitted
    generation step (and under shard_map on a mesh); host-only backends
    are driven by GPSession's host generation loop instead.

    fitness/moments/stream_moments also accept `dedup`/`dedup_cap`
    (static): any value other than ``"off"`` engages the exact-tier
    population-wide subexpression dedup for postfix genomes — each
    distinct subtree evaluated once per call, bitwise-identical results.
    Backends may ignore the flag (the scalar baseline does).
    """

    name: str
    evaluate: Callable
    fitness: Callable
    moments: Callable = None
    stream_moments: Callable = None
    jittable: bool = True
    supports_topology: bool = True
    fused_fitness: bool = False  # evaluation+reduction in one kernel
    description: str = ""

    def capabilities(self) -> dict:
        return {"name": self.name, "jittable": self.jittable,
                "supports_topology": self.supports_topology,
                "fused_fitness": self.fused_fitness,
                "description": self.description}


_REGISTRY: dict[str, EvalBackend] = {}


def register_backend(backend: EvalBackend, *, overwrite: bool = False) -> EvalBackend:
    if backend.name in _REGISTRY and not overwrite:
        raise ValueError(f"eval backend {backend.name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name: str) -> EvalBackend:
    if name == "auto":
        return _REGISTRY[auto_select()]
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown eval backend {name!r}; registered: "
                         f"{available_backends()}") from None


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


def auto_select() -> str:
    """Backend auto-selection: `pallas` when running on TPU (the fused
    VMEM-resident kernel is the point of that hardware), `jnp` everywhere
    else (Pallas interpret mode is a validation tool, not a fast path).
    `scalar` is never auto-selected — it exists to be measured against."""
    import jax

    return "pallas" if jax.default_backend() == "tpu" else "jnp"


# --- built-in backends --------------------------------------------------------


def _jnp_evaluate(op, arg, X, const_table, tree_spec):
    from repro.core.eval import evaluate_population

    return evaluate_population(op, arg, X, const_table, tree_spec)


def _jnp_fitness(op, arg, X, y, const_table, tree_spec, fit_spec, weight=None,
                 data_tile=1024, dedup="off", dedup_cap=0):
    from repro.kernels.ref import fitness_ref_tiled

    return fitness_ref_tiled(op, arg, X, y, const_table, tree_spec, fit_spec,
                             weight=weight, dedup=dedup, dedup_cap=dedup_cap)


def _jnp_moments(op, arg, X, y, const_table, tree_spec, fit_spec, weight=None,
                 data_tile=1024, dedup="off", dedup_cap=0):
    from repro.kernels.ref import moments_ref_tiled

    return moments_ref_tiled(op, arg, X, y, const_table, tree_spec, fit_spec,
                             weight=weight, dedup=dedup, dedup_cap=dedup_cap)


def _pallas_fitness(op, arg, X, y, const_table, tree_spec, fit_spec, weight=None,
                    data_tile=1024, dedup="off", dedup_cap=0):
    from repro.kernels import ops as kops

    return kops.fitness(op, arg, X, y, const_table, tree_spec, fit_spec,
                        weight=weight, data_tile=data_tile, dedup=dedup,
                        dedup_cap=dedup_cap)


def _pallas_moments(op, arg, X, y, const_table, tree_spec, fit_spec, weight=None,
                    data_tile=1024, dedup="off", dedup_cap=0):
    from repro.kernels import ops as kops

    return kops.moments(op, arg, X, y, const_table, tree_spec, fit_spec,
                        weight=weight, data_tile=data_tile, dedup=dedup,
                        dedup_cap=dedup_cap)


def _jnp_stream_moments(acc, op, arg, X, y, const_table, tree_spec, fit_spec,
                        weight=None, data_tile=1024, dedup="off", dedup_cap=0):
    from repro.kernels import ops as kops

    return kops.stream_moments(acc, op, arg, X, y, const_table, tree_spec,
                               fit_spec, weight=weight, data_tile=data_tile,
                               impl="jnp", dedup=dedup, dedup_cap=dedup_cap)


def _pallas_stream_moments(acc, op, arg, X, y, const_table, tree_spec, fit_spec,
                           weight=None, data_tile=1024, dedup="off",
                           dedup_cap=0):
    from repro.kernels import ops as kops

    return kops.stream_moments(acc, op, arg, X, y, const_table, tree_spec,
                               fit_spec, weight=weight, data_tile=data_tile,
                               impl="pallas", dedup=dedup, dedup_cap=dedup_cap)


def _scalar_evaluate(op, arg, X, const_table, tree_spec):
    from repro.core.scalar_eval import evaluate_population_scalar

    X_rows = np.ascontiguousarray(np.asarray(X, np.float32).T)  # [F,D] -> [D,F]
    return evaluate_population_scalar(np.asarray(op), np.asarray(arg),
                                      X_rows, np.asarray(const_table),
                                      genome=tree_spec.genome)


def _scalar_fitness(op, arg, X, y, const_table, tree_spec, fit_spec, weight=None,
                    data_tile=1024, dedup="off", dedup_cap=0):
    # dedup ignored: the scalar baseline exists to be measured against,
    # and the exact tier is bitwise-identical by contract anyway
    from repro.core.scalar_eval import fitness_scalar

    X_rows = np.ascontiguousarray(np.asarray(X, np.float32).T)
    return fitness_scalar(np.asarray(op), np.asarray(arg), X_rows,
                          np.asarray(y), np.asarray(const_table),
                          kernel=fit_spec.kernel, n_classes=fit_spec.n_classes,
                          precision=fit_spec.precision,
                          weight=None if weight is None else np.asarray(weight),
                          genome=tree_spec.genome)


def _scalar_moments(op, arg, X, y, const_table, tree_spec, fit_spec, weight=None,
                    data_tile=1024, dedup="off", dedup_cap=0):
    # the scalar backend is host-only and never runs under shard_map; the
    # moment pass exists so host-side tools can inspect every backend
    # through one contract
    from repro.core.fitness import moments_from_preds

    preds = _scalar_evaluate(op, arg, X, const_table, tree_spec)
    w = None if weight is None else np.asarray(weight, np.float32)
    return np.asarray(moments_from_preds(preds, np.asarray(y, np.float32),
                                         fit_spec, weight=w))


def _scalar_stream_moments(acc, op, arg, X, y, const_table, tree_spec, fit_spec,
                           weight=None, data_tile=1024, dedup="off",
                           dedup_cap=0):
    # host fold: scalar evaluation of the chunk, then the kernel's merge —
    # the streaming contract holds on the paper-faithful baseline too
    from repro.core.fitness import get_kernel

    m = _scalar_moments(op, arg, X, y, const_table, tree_spec, fit_spec,
                        weight=weight)
    kern = get_kernel(fit_spec.kernel)
    return np.asarray(kern.merge_moments(np.asarray(acc, np.float32), m,
                                         fit_spec))


@functools.lru_cache(maxsize=64)
def host_next_generation(tree_spec, mix, tourn_size: int, elitism: int):
    """One jitted `next_generation` per (spec, mix, tourn_size, elitism),
    cached across call sites and sessions — the host generation loop
    (scalar backend) re-enters the SAME compiled program every generation
    instead of paying a fresh trace per call site."""
    import jax

    from repro.core import evolve as ev

    def fn(key, op, arg, fitness):
        return ev.next_generation(key, op, arg, fitness, tree_spec, mix,
                                  tourn_size, elitism)

    return jax.jit(fn)


@functools.lru_cache(maxsize=64)
def host_next_generation_islands(tree_spec, island_cfg, mix, tourn_size: int,
                                 elitism: int):
    """Island-batched sibling of `host_next_generation`: ONE jitted
    program per (spec, island config, mix, tourn_size, elitism) that
    vmaps `next_generation_arrays` over the island axis with each
    island's operator parameters — the scalar backend's host loop runs
    the same heterogeneous-search semantics as the jitted engine paths.
    fn(keys [I,2], op [I,P,N], arg, fitness [I,P]) -> (keys, op, arg)."""
    import jax
    import jax.numpy as jnp

    from repro.core import evolve as ev

    probs = island_cfg.prob_table(mix)
    tourn_max, tourn = island_cfg.tourn_table(tourn_size)
    p_point = island_cfg.point_rate_table()
    breed = ev.make_island_breeder(tree_spec, tourn_max, elitism)

    def fn(keys, op, arg, fitness):
        return jax.vmap(breed)(keys, op, arg, fitness, jnp.asarray(probs),
                               jnp.asarray(tourn), jnp.asarray(p_point))

    return jax.jit(fn)


register_backend(EvalBackend(
    name="jnp", evaluate=_jnp_evaluate, fitness=_jnp_fitness,
    moments=_jnp_moments, stream_moments=_jnp_stream_moments,
    description="vectorized XLA level-sweep (paper's *-CPU_TF)"))
register_backend(EvalBackend(
    name="pallas", evaluate=_jnp_evaluate, fitness=_pallas_fitness,
    moments=_pallas_moments, stream_moments=_pallas_stream_moments,
    fused_fitness=True,
    description="fused eval+fitness Pallas TPU kernel (interpret off-TPU)"))
register_backend(EvalBackend(
    name="scalar", evaluate=_scalar_evaluate, fitness=_scalar_fitness,
    moments=_scalar_moments, stream_moments=_scalar_stream_moments,
    jittable=False, supports_topology=False,
    description="paper-faithful per-data-point interpreter (1-CPU_SP)"))
