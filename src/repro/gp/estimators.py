"""sklearn-style facades over GPSession.

`SymbolicRegressor` / `SymbolicClassifier` follow the estimator protocol
(constructor holds hyper-parameters; `fit`/`predict`/`score`; fitted
attributes carry a trailing underscore; `warm_start=True` continues
evolving the previous population on the next `fit`). They are thin: all
execution — backends, topologies, checkpointing — is the session's.
"""
from __future__ import annotations

import numpy as np

from repro.gp.session import GPSession


class _SymbolicBase:
    _kernel = "r"

    def __init__(self, *, pop_size: int = 100, generations: int = 30,
                 max_depth: int = 5, n_consts: int = 8, fn_set=None,
                 tourn_size: int = 10, elitism: int = 1, parsimony: float = 0.0,
                 stop_fitness: float | None = None, backend: str | None = None,
                 topology=None, checkpoint_dir: str | None = None,
                 random_state: int = 0, warm_start: bool = False,
                 block_size: int | None = None, chunk_rows: int | None = None,
                 islands: int = 1,
                 migrate_every: int = 10, migrate_k: int = 4,
                 island_topology: str = "ring", island_mixes=None):
        self.pop_size = pop_size
        self.generations = generations
        self.max_depth = max_depth
        self.n_consts = n_consts
        self.fn_set = fn_set
        self.tourn_size = tourn_size
        self.elitism = elitism
        self.parsimony = parsimony
        self.stop_fitness = stop_fitness
        self.backend = backend
        self.topology = topology
        self.checkpoint_dir = checkpoint_dir
        self.random_state = random_state
        self.warm_start = warm_start
        # generations per device-resident evolution block (None = whole run
        # in one dispatch, bounded by the checkpoint period when set)
        self.block_size = block_size
        # streaming chunked fitness: evaluate fit() data as a fold over
        # fixed chunk_rows-sized chunks instead of one device-resident
        # array (None = monolithic) — docs/fitness-kernels.md#streaming
        self.chunk_rows = chunk_rows
        # island-model layout: islands of pop_size trees each, periodic
        # elite migration, optional per-island operator mixes — see
        # docs/islands.md
        self.islands = islands
        self.migrate_every = migrate_every
        self.migrate_k = migrate_k
        self.island_topology = island_topology
        self.island_mixes = island_mixes

    def _kernel_overrides(self) -> dict:
        return {"kernel": self._kernel}

    def _make_session(self) -> GPSession:
        import jax

        overrides = dict(pop_size=self.pop_size, generations=self.generations,
                         max_depth=self.max_depth, n_consts=self.n_consts,
                         tourn_size=self.tourn_size, elitism=self.elitism,
                         parsimony=self.parsimony, stop_fitness=self.stop_fitness,
                         islands=self.islands, migrate_every=self.migrate_every,
                         migrate_k=self.migrate_k,
                         island_topology=self.island_topology,
                         **self._kernel_overrides())
        if self.island_mixes is not None:
            overrides["island_mixes"] = tuple(self.island_mixes)
        if self.fn_set is not None:
            overrides["fn_set"] = self.fn_set
        self._key = jax.random.PRNGKey(self.random_state)
        return GPSession(backend=self.backend, topology=self.topology,
                         checkpoint_dir=self.checkpoint_dir,
                         block_size=self.block_size,
                         chunk_rows=self.chunk_rows, **overrides)

    def fit(self, X, y):
        """Evolve on X [n_samples, n_features], y [n_samples]. Blocks
        until the run finishes (the session synchronizes once per
        evolution block); fitted attributes `expression_` (str),
        `best_fitness_` (float, minimize) and `n_features_in_` are host
        values. With warm_start=True a second fit continues the evolved
        population instead of reinitializing."""
        cont = self.warm_start and getattr(self, "session_", None) is not None
        if not cont:
            self.session_ = self._make_session()
        self.session_.fit(X, y, key=self._key, warm_start=cont)
        self.expression_ = self.session_.best_expression()
        self.best_fitness_ = self.session_.best_fitness
        self.n_features_in_ = self.session_.config.tree_spec.n_features
        return self

    def _raw_predict(self, X) -> np.ndarray:
        if getattr(self, "session_", None) is None:
            raise ValueError("estimator is not fitted; call fit(X, y) first")
        return self.session_.predict(X)


class SymbolicRegressor(_SymbolicBase):
    """GP symbolic regression (the paper's (r) kernel by default; pass
    kernel-capable subclasses or register new FitnessKernels for others).
    `backend=` / `topology=` forward to GPSession, so the same estimator
    runs the scalar baseline, the Pallas kernel, or a device mesh."""

    _kernel = "r"

    def predict(self, X) -> np.ndarray:
        """Champion expression on X [n_samples, n_features] ->
        f32[n_samples] host array (one device sync)."""
        return self._raw_predict(X)

    def score(self, X, y) -> float:
        """R² (sklearn's regressor convention), computed on the host in
        float64; 1.0 is a perfect fit, can be arbitrarily negative."""
        y = np.asarray(y, np.float64)
        pred = np.asarray(self.predict(X), np.float64)
        ss_res = float(((y - pred) ** 2).sum())
        ss_tot = float(((y - y.mean()) ** 2).sum())
        return 1.0 - ss_res / ss_tot if ss_tot > 0 else 0.0


class SymbolicClassifier(_SymbolicBase):
    """GP classification via Karoo's round-and-clip label binning: the
    evolved expression's float output is rounded and clipped into
    {0..n_classes-1} (fitness counts weighted hits, minimize-negated)."""

    _kernel = "c"

    def __init__(self, *, n_classes: int = 3, **kw):
        super().__init__(**kw)
        self.n_classes = n_classes

    def _kernel_overrides(self) -> dict:
        return {"kernel": self._kernel, "n_classes": self.n_classes}

    def predict(self, X) -> np.ndarray:
        """Labels int32[n_samples] in {0..n_classes-1} for
        X [n_samples, n_features] (host array, one device sync)."""
        from repro.core.fitness import classify_labels

        return np.asarray(classify_labels(
            np.nan_to_num(self._raw_predict(X)), self.n_classes))

    def score(self, X, y) -> float:
        """Accuracy in [0, 1] (sklearn's classifier convention)."""
        return float((self.predict(X) == np.asarray(y).astype(np.int64)).mean())
