"""GPSession — one front door for every GP run shape.

The paper's claim is one algorithm across platforms and five orders of
magnitude of dataset size; the session makes that a one-line switch:

    from repro.gp import GPSession, MeshTopology

    # single device, auto-selected backend
    GPSession(pop_size=200, kernel="r").fit(X_rows, y)

    # explicit platform (paper's scalar/vector axis)
    GPSession(backend="scalar").fit(X_rows, y)      # 1-CPU_SP baseline
    GPSession(backend="pallas").fit(X_rows, y)      # fused TPU kernel

    # mesh/island run — PartitionSpec plumbing stays internal
    GPSession(topology=MeshTopology(data=2, model=2, pod=2)).fit(X_rows, y)

    # island-model run: 4 islands of 200 trees on ANY of the above —
    # one CPU device, a flat mesh, or pods × in-device islands; the
    # same fit() call, per-island best-fitness streams in
    # session.island_history
    GPSession(pop_size=200, islands=4, migrate_every=5).fit(X_rows, y)

The session owns the full lifecycle: data ingestion (`data/loader`
transposition + padding + device placement), state init/seeding
(`core.parse`), the generation loop, early stopping, periodic
checkpointing (`ckpt/`), and best-tree decoding (`trees.to_string`).

The loop is driven in device-resident *evolution blocks*: `evolve()`
dispatches `engine.evolve_block` (a `lax.scan` over K generations —
`sharded_evolve_block` on a mesh) and synchronizes with the device once
per block, reading back the final state plus the [K] per-generation
best-fitness history. Early stop (`cfg.stop_fitness`) is a branch-free
on-device freeze checked on the host only at block boundaries; the
block size is min(checkpoint period, callback period, remaining
generations), so checkpoints and callbacks still fire exactly when
configured. Datasets whose row count doesn't divide the mesh's data
axis are padded (`data/loader.pad_rows`) with a zero-weight mask that
keeps fitness exact. `session.stats["host_syncs"]` counts the actual
host synchronizations, pinned by tests to ≤ ⌈generations/K⌉.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core import engine
from repro.core import fitness as fit
from repro.core import primitives as prim
from repro.core.engine import GPConfig, GPState
from repro.core.trees import to_string, tree_sizes
from repro.data.loader import feature_major
from repro.gp import backends as _backends
from repro.obs import counters as _tc
from repro.obs.metrics import BlockMonitor, Metrics
from repro.obs.trace import NULL_TRACER
from repro.runtime.fault import StepMonitor as _StepMonitor


@dataclasses.dataclass(frozen=True)
class MeshTopology:
    """Device-mesh shape for a sharded run; `data * model * pod` must not
    exceed the process's device count.

    data   shards dataset columns: `X f32[F, D]`, `y f32[D]` and the
           padding mask `weight f32[D]` split on D; each shard's [P, M]
           fitness moments psum-reduce across this axis (two-pass
           protocol, so every registered kernel — pearson/r2 included —
           shards here). Rows that don't divide `data` are zero-weight
           padded by `GPSession.ingest`, so any row count is legal.
    model  shards the population (op/arg int32[P, N] split on P);
           selection all_gathers the pod's fitness + parent pool (tiny
           next to evaluation).
    pod    island parallelism. Classic layout (islands=1): each pod
           slice evolves an independent sub-population with periodic
           elite ring migration. Island-batched layout (islands=I > 1):
           the pod axis shards the ISLAND axis — I/n_pods in-device
           islands per pod, migration composed across both levels
           (`core/islands.py`); `migrate_every`/`migrate_k`/
           `island_topology` configure it.

    Purely declarative — `build()` materializes the jax Mesh; GPSession
    calls it lazily and keeps all PartitionSpec plumbing internal."""

    data: int = 1
    model: int = 1
    pod: int = 1

    def build(self):
        """Materialize the jax.sharding.Mesh (host-local devices)."""
        from repro.launch.mesh import make_host_mesh

        return make_host_mesh(data=self.data, model=self.model, pod=self.pod)


_TREE_KEYS = ("max_depth", "n_features", "n_consts", "fn_set", "p_const",
              "grow_p_fn", "genome")
_FIT_KEYS = ("kernel", "n_classes", "precision")
# flat spellings of IslandConfig fields (migrate_every/migrate_k ride the
# GPConfig legacy aliases); "islands" is the headline front-door knob
_ISLAND_KEYS = {"islands": "islands", "island_topology": "topology",
                "island_mixes": "mixes", "island_tourn_sizes": "tourn_sizes",
                "island_point_rates": "point_rates"}


def make_config(config: GPConfig | None = None, **overrides) -> GPConfig:
    """GPConfig from flat keyword overrides — tree/fitness/island sub-spec
    keys (max_depth, kernel, islands, island_topology, ...) land on the
    right nested dataclass, so callers never hand-assemble
    TreeSpec/FitnessSpec/IslandConfig for common runs."""
    config = config if config is not None else GPConfig()
    tree_kw = {k: overrides.pop(k) for k in _TREE_KEYS if k in overrides}
    fit_kw = {k: overrides.pop(k) for k in _FIT_KEYS if k in overrides}
    island_kw = {v: overrides.pop(k) for k, v in _ISLAND_KEYS.items()
                 if k in overrides}
    if island_kw:
        config = dataclasses.replace(
            config, island=dataclasses.replace(config.island, **island_kw))
    fn_set = tree_kw.get("fn_set")
    if isinstance(fn_set, str):
        tree_kw["fn_set"] = prim.FunctionSet.make(tuple(fn_set.split(",")))
    elif isinstance(fn_set, (list, tuple)):
        tree_kw["fn_set"] = prim.FunctionSet.make(tuple(fn_set))
    if tree_kw:
        config = dataclasses.replace(
            config, tree_spec=dataclasses.replace(config.tree_spec, **tree_kw))
    if fit_kw:
        config = dataclasses.replace(
            config, fitness=dataclasses.replace(config.fitness, **fit_kw))
    if overrides:
        config = dataclasses.replace(config, **overrides)
    return config


class GPSession:
    """Owns one GP run: config + backend + topology + state + loop.

    Lifecycle: `ingest(X, y)` → `init(key=)` → `evolve(n)` (or `fit`,
    which chains all three). `state` is the device-resident GPState
    pytree (population int32[P, N] op/arg pairs, f32[P] fitness,
    champion tree + f32 best_fitness, int32 generation); properties
    `generation`/`best_fitness` read it back (one host sync each), while
    `history` (floats, one per generation run) and `stats`
    ('host_syncs'/'blocks' counters) are host-side and free to read.
    Keyword overrides (pop_size=, kernel=, max_depth=, ...) land on the
    right nested GPConfig dataclass via `make_config`.

    `islands=I` (plus `migrate_every=`, `migrate_k=`, `island_topology=`,
    `island_mixes=`, `island_tourn_sizes=`, `island_point_rates=`) turns
    the run into I islands of `pop_size` trees on whatever backend and
    topology the session already uses — every GPState population leaf
    grows a leading island axis, `island_history` streams each island's
    best fitness per generation, `best_expression()`/`predict()` decode
    the best across all islands, and `island_expressions()` lists every
    island's champion. With a pod-axis mesh the islands spread over pods
    (islands % pod == 0); `islands=1` is bitwise the classic layout."""

    def __init__(self, config: GPConfig | None = None, *, backend: str | None = None,
                 topology: "MeshTopology | object | None" = None,
                 checkpoint_dir: str | None = None, checkpoint_every: int = 10,
                 feature_names=None, callback=None, callback_every: int = 1,
                 block_size: int | None = None, chunk_rows: int | None = None,
                 tracer=None, metrics=None, **overrides):
        explicit_features = (config is not None or "tree_spec" in overrides
                             or "n_features" in overrides)
        explicit_impl = config is not None or "eval_impl" in overrides
        self._cfg = make_config(config, **overrides)
        if backend is None:
            backend = self._cfg.eval_impl if explicit_impl else "auto"
        self._backend = _backends.get_backend(backend)
        if self._backend.jittable:
            self._cfg = dataclasses.replace(self._cfg, eval_impl=self._backend.name)
        self._explicit_features = explicit_features
        self._topology = topology
        self._mesh = None
        self._step_fn = None  # jitted sharded step (step() contract)
        self._block_cache = {}  # n_steps -> jitted sharded block
        self._built_for = None  # (cfg, mesh) the jitted step was built for
        self._specs = None
        self._X = None
        self._y = None
        self._weight = None  # f32[D'] padding mask (mesh runs only)
        # streaming chunked ingest: evaluate datasets larger than device
        # memory by folding fixed-shape chunks (docs/fitness-kernels.md)
        self._chunk_rows = chunk_rows  # default for ingest(chunk_rows=)
        self._stream = None  # ChunkedDataset when ingest chunked
        self._stream_fold = None  # jitted mesh fold (engine.build_stream_fold)
        self._n_rows = 0  # REAL (pre-padding) row count
        self._gen_host = 0  # host mirror of state.generation (no device read)
        self._gen_dirty = False  # mirror stale (raw evolve_block + stop_fitness)
        self.state: GPState | None = None
        self.history: list[float] = []
        # island runs: one f32[I] row per generation (per-island best-
        # fitness streams); stays empty for the classic layout
        self.island_history: list[np.ndarray] = []
        self.stats = {"host_syncs": 0, "blocks": 0, "block_s_ema": None,
                      "stragglers": [], "cache_hits": 0, "cache_queries": 0,
                      "cache_hit_rate": 0.0, "frozen": 0, "migrations": 0,
                      "tree_evals": 0}
        self._monitor = _StepMonitor()  # per-block wall time EMA + stragglers
        # observability (repro.obs): tracer spans + metrics registry are
        # host-side only — the compiled programs are identical with or
        # without them (the counter stream is unconditional), so these
        # defaults cost nothing and enabling them changes no trajectory
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else Metrics()
        # THE timing path for every block dispatch — jitted, host-loop,
        # and streamed alike — so block_s_ema/stragglers report everywhere
        self._block_monitor = BlockMonitor(self._monitor, self.metrics,
                                           self.stats)
        self._last_counters = None  # device [K, C] from a raw evolve_block
        self.feature_names = list(feature_names) if feature_names else None
        self._callback = callback
        self._callback_every = max(1, int(callback_every))
        self._block_size = block_size
        self._manager = None
        if checkpoint_dir:
            from repro.ckpt.checkpoint import CheckpointManager

            self._manager = CheckpointManager(checkpoint_dir, every=checkpoint_every)
        if topology is not None and not self._backend.supports_topology:
            raise ValueError(f"backend {self._backend.name!r} does not support "
                             f"mesh topologies (host-only)")

    # --- introspection -------------------------------------------------------

    @property
    def config(self) -> GPConfig:
        return self._cfg

    @property
    def backend(self) -> str:
        return self._backend.name

    @property
    def generation(self) -> int:
        return int(self.state.generation) if self.state is not None else 0

    @property
    def islands(self) -> int:
        """Number of islands in the population layout (1 = classic)."""
        return self._cfg.island.islands

    @property
    def best_fitness(self) -> float:
        """Best fitness seen so far — across ALL islands for an
        island-batched run (one host sync)."""
        if self.state is None:
            return float("inf")
        bf = np.asarray(self.state.best_fitness)
        return float(bf.min()) if bf.ndim else float(bf)

    @property
    def island_best_fitness(self) -> np.ndarray:
        """f32[I] per-island champion fitness (one host sync)."""
        self._require_state()
        return np.atleast_1d(np.asarray(self.state.best_fitness))

    @property
    def n_rows(self) -> int:
        """REAL data points currently ingested (0 before ingest; excludes
        any zero-weight padding added to shard exactly)."""
        return self._n_rows

    @property
    def mesh(self):
        if self._mesh is None and self._topology is not None:
            top = self._topology
            self._mesh = top.build() if isinstance(top, MeshTopology) else top
        return self._mesh

    def _pod_axis(self):
        mesh = self.mesh
        return "pod" if mesh is not None and "pod" in mesh.axis_names else None

    def build_sharded_step(self):
        """(step_fn, specs) of the mesh generation step — step_fn(state,
        X, y, weight); `step()` drives it internally."""
        if self.mesh is None:
            raise ValueError("build_sharded_step needs a topology= mesh")
        return engine.sharded_evolve_step(self._cfg, self.mesh,
                                          pod_axis=self._pod_axis())

    def build_sharded_block(self, n_steps: int):
        """(block_fn, specs) of the K-generation mesh evolution block —
        the lowering surface used by launch/dryrun.py; `evolve()` drives
        it internally. block_fn(state, X, y, weight, limit) ->
        (state, history, counters)."""
        if self.mesh is None:
            raise ValueError("build_sharded_block needs a topology= mesh")
        return engine.sharded_evolve_block(self._cfg, self.mesh, n_steps=n_steps,
                                           pod_axis=self._pod_axis())

    # --- lifecycle -----------------------------------------------------------

    def ingest(self, X=None, y=None, *, layout: str = "rows",
               sample_weight=None, stream=None,
               chunk_rows: int | None = None) -> "GPSession":
        """Load the dataset onto the session's devices. layout='rows' is
        sklearn-style [rows, features] float data (transposed to the
        paper's feature-major f32[F, D] Eq. 2 form internally);
        layout='features' accepts already-transposed [features, rows].
        y is f32[D] targets (class ids as floats for the 'c' kernel).
        `sample_weight` (f32[D], optional) scales each point's fitness
        contribution; 0.0 excludes a point exactly (every kernel's
        padding contract), so pre-padded data — e.g. a service job's
        slot buffer replayed solo — evaluates bit-for-bit. On a mesh,
        rows that don't divide the data axis are padded with a
        zero-weight mask (fitness stays exact; `n_rows` reports the real
        count; sample weights compose with the mask) and X/y/weight are
        device_put sharded; single-device jittable backends get plain
        device arrays; host-only backends keep numpy. Synchronous host
        work only — no device compute.

        Streaming front door — datasets larger than device memory:
        `chunk_rows=` (here or on the constructor) evaluates X/y as a
        fold over fixed `[F, chunk_rows]` zero-weight-padded chunks, and
        `stream=` accepts a `data/loader.ChunkedDataset`, a memmapped
        array, or a callable/iterator of `(X, y[, weight])` row blocks.
        Fitness parity with monolithic ingest is pinned (bitwise for
        decomposable kernels, ≤1e-4 for pearson/r2); evolution advances
        one generation per host-driven chunk fold, so peak device
        footprint is ONE chunk regardless of total rows. On a mesh each
        chunk is sharded on the data axis (chunk_rows rounds up to a
        multiple of it)."""
        with self.tracer.span("ingest"):
            out = self._ingest(X, y, layout=layout,
                               sample_weight=sample_weight, stream=stream,
                               chunk_rows=chunk_rows)
        self.metrics.gauge("rows", self._n_rows)
        return out

    def _ingest(self, X=None, y=None, *, layout, sample_weight, stream,
                chunk_rows) -> "GPSession":
        if stream is not None or chunk_rows is not None or (
                self._chunk_rows is not None):
            return self._ingest_stream(X, y, layout=layout,
                                       sample_weight=sample_weight,
                                       stream=stream, chunk_rows=chunk_rows)
        self._stream = None
        self._stream_fold = None
        if X is None or y is None:
            raise ValueError("ingest needs X and y (or stream=)")
        X = np.asarray(X, np.float32)
        y = np.asarray(y, np.float32)
        if sample_weight is not None:
            sample_weight = np.asarray(sample_weight, np.float32)
        if layout == "rows":
            X_fm = feature_major(X)
        elif layout == "features":
            X_fm = np.ascontiguousarray(X)
        else:
            raise ValueError(f"layout must be 'rows' or 'features', got {layout!r}")
        F, D = X_fm.shape
        if y.shape != (D,):
            raise ValueError(f"y shape {y.shape} does not match {D} data points")
        spec = self._cfg.tree_spec
        if spec.n_features != F:
            if self._explicit_features:
                raise ValueError(f"TreeSpec.n_features={spec.n_features} but the "
                                 f"dataset has {F} features")
            self._cfg = dataclasses.replace(
                self._cfg, tree_spec=dataclasses.replace(spec, n_features=F))

        self._n_rows = D
        if sample_weight is not None and sample_weight.shape != (D,):
            raise ValueError(f"sample_weight shape {sample_weight.shape} does "
                             f"not match {D} data points")
        if self.mesh is not None:
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P

            from repro.data.loader import pad_feature_major

            # pad rows up to the data axis; the zero-weight mask threads
            # through every fitness kernel, so sharding is always exact
            n_data = self.mesh.shape["data"]
            X_fm, y, w = pad_feature_major(X_fm, y, n_data)
            if sample_weight is not None:
                w = w * np.pad(sample_weight, (0, w.shape[0] - D))
            if self._step_fn is None or self._built_for != (self._cfg, self.mesh):
                # warm_start refits reuse the jitted programs; rebuild only
                # when the config or mesh actually changed
                step, self._specs = self.build_sharded_step()
                with compat.set_mesh(self.mesh):
                    self._step_fn = jax.jit(step, donate_argnums=(0,))
                self._block_cache = {}
                self._built_for = (self._cfg, self.mesh)
            self._X = jax.device_put(X_fm, NamedSharding(self.mesh, P(None, "data")))
            self._y = jax.device_put(y, NamedSharding(self.mesh, P("data")))
            self._weight = jax.device_put(w, NamedSharding(self.mesh, P("data")))
        elif self._backend.jittable:
            self._X = jnp.asarray(X_fm)
            self._y = jnp.asarray(y)
            # single device never pads; an explicit weight threads through
            self._weight = None if sample_weight is None else jnp.asarray(sample_weight)
        else:
            self._X, self._y = X_fm, y
            self._weight = sample_weight
        self._invalidate_elite_cache()
        return self

    def _invalidate_elite_cache(self):
        """New data invalidates the elite fitness cache (cached scores
        were measured against the old dataset) — reset to the
        never-matching init, so the next generation re-evaluates."""
        if self.state is not None and self.state.cache_fit.size:
            self.state = self.state._replace(
                cache_op=jnp.zeros_like(self.state.cache_op),
                cache_arg=jnp.zeros_like(self.state.cache_arg),
                cache_fit=jnp.full_like(self.state.cache_fit, jnp.inf))

    def _ingest_stream(self, X, y, *, layout, sample_weight, stream,
                       chunk_rows) -> "GPSession":
        """Streaming half of `ingest`: wrap the source in a fixed-shape
        `ChunkedDataset` (or adopt one), infer n_features from it, and
        arm the per-generation chunk fold. On a mesh, `chunk_rows` rounds
        up to a multiple of the data axis and `engine.build_stream_fold`
        shards every chunk exactly like the monolithic step would."""
        from repro.data.loader import ChunkedDataset

        if stream is not None and X is not None:
            raise ValueError("pass either X/y or stream=, not both")
        chunk_rows = chunk_rows if chunk_rows is not None else self._chunk_rows
        n_data = self.mesh.shape["data"] if self.mesh is not None else 1
        if isinstance(stream, ChunkedDataset):
            ds = stream
            if chunk_rows is not None and int(chunk_rows) != ds.chunk_rows:
                raise ValueError(f"chunk_rows={chunk_rows} conflicts with the "
                                 f"ChunkedDataset's chunk_rows={ds.chunk_rows}")
            if ds.chunk_rows % n_data:
                raise ValueError(f"chunk_rows={ds.chunk_rows} must be a "
                                 f"multiple of the mesh data axis ({n_data})")
        else:
            if chunk_rows is None:
                raise ValueError("stream= needs chunk_rows= (constructor or "
                                 "ingest keyword), or pass a ChunkedDataset")
            rows = int(chunk_rows)
            rows += (-rows) % n_data  # mesh: every chunk shards exactly
            ds = ChunkedDataset(stream if stream is not None else X, y,
                                chunk_rows=rows, layout=layout,
                                sample_weight=sample_weight)
        F = ds.n_features
        spec = self._cfg.tree_spec
        if spec.n_features != F:
            if self._explicit_features:
                raise ValueError(f"TreeSpec.n_features={spec.n_features} but "
                                 f"the dataset has {F} features")
            self._cfg = dataclasses.replace(
                self._cfg, tree_spec=dataclasses.replace(spec, n_features=F))
        self._stream = ds
        self._X = self._y = self._weight = None
        self._n_rows = ds.n_rows or 0
        self._stream_fold = (engine.build_stream_fold(self._cfg, self.mesh)
                             if self.mesh is not None else None)
        self._invalidate_elite_cache()
        return self

    def init(self, *, key=None, seeds=None) -> "GPSession":
        """Fresh state (or checkpoint restore when a checkpoint_dir holds
        one). `seeds` are expression strings — Karoo's customized seed
        populations, parsed against the session's TreeSpec."""
        if self._X is None and self._stream is None:
            raise ValueError("no dataset — call ingest()/fit() first")
        key = key if key is not None else jax.random.PRNGKey(0)
        with self.tracer.span("init"):
            self.state = engine.init_state(self._cfg, key, seeds=seeds,
                                           feature_names=self.feature_names)
            self.history = []
            self.island_history = []
            self._gen_host = 0
            self._gen_dirty = False
            if self._manager is not None:
                restored, step = self._manager.restore_latest(
                    like=jax.device_get(self.state))
                if restored is not None:
                    self.state = jax.tree.map(jnp.asarray, restored)
                    self._gen_host = int(step)
        return self

    # --- slot-level state swap (the service scheduler's surface) -------------

    def export_island(self, idx: int):
        """Island `idx`'s slice of the session state as an un-batched
        sub-state pytree (leading island axis dropped; the shared
        generation scalar rides along unchanged) — what a multi-tenant
        scheduler lifts out of a batch when a slot's job finishes. Pure
        host-eager slicing; no recompilation, no state mutation."""
        from repro.core.islands import take_island

        self._require_state()
        if self.islands <= 1:
            raise ValueError("export_island needs an island-batched run "
                             "(islands > 1)")
        if not 0 <= idx < self.islands:
            raise ValueError(f"island {idx} out of range [0, {self.islands})")
        return take_island(self.state, idx)

    def import_island(self, idx: int, sub) -> "GPSession":
        """Replace island slot `idx` with `sub` (an `export_island` slice
        or any identically-shaped sub-state, e.g. a freshly initialized
        one) — admission half of the slot swap. Eager `.at[].set`
        updates on the live state; the compiled step/block programs are
        untouched, so swapping populations between blocks never triggers
        a recompile."""
        from repro.core.islands import splice_island

        self._require_state()
        if self.islands <= 1:
            raise ValueError("import_island needs an island-batched run "
                             "(islands > 1)")
        if not 0 <= idx < self.islands:
            raise ValueError(f"island {idx} out of range [0, {self.islands})")
        self.state = splice_island(self.state, idx, sub)
        return self

    def adopt_state(self, state: GPState) -> "GPSession":
        """Install an externally built GPState (a checkpoint restored and
        resharded elsewhere, a spliced batch, ...) as the session's live
        state and resynchronize the host generation mirror — one host
        sync, then the evolve loop continues from it as if the session
        had produced it."""
        self.state = jax.tree.map(jnp.asarray, state)
        self._gen_host = int(self.state.generation)
        self._gen_dirty = False
        return self

    def step(self) -> GPState:
        """One generation, unconditionally (no early-stop freeze). Does not
        synchronize with the device — callers timing the hot loop
        (benchmarks/) see pure step throughput."""
        if self.state is None:
            self.init()
        if self._stream is not None:
            # streamed datasets fold chunk-by-chunk on the host loop —
            # every backend and layout, mesh included (the fold shards
            # each chunk on the data axis)
            self.state = self._host_step(self.state)
        elif self._step_fn is not None:
            with compat.set_mesh(self.mesh):
                self.state = self._step_fn(self.state, self._X, self._y,
                                           self._weight)
        elif self._backend.jittable:
            self.state = engine.evolve_step(self._cfg, self.state, self._X,
                                            self._y, self._weight)
        else:
            self.state = self._host_step(self.state)
        self._gen_host += 1
        return self.state

    def evolve_block(self, n_steps: int) -> tuple[GPState, jax.Array]:
        """Run `n_steps` generations in ONE device dispatch (`lax.scan`
        block; scan-inside-shard_map on a mesh). Updates the session state
        and returns (state, history) WITHOUT synchronizing with the host —
        history is the device-resident f32[n_steps] best-fitness stream.
        The block's telemetry counter stream stays device-resident too;
        `absorb_block_telemetry()` folds it into `stats` on demand (one
        sync), while `evolve()` — which drives this and owns the
        block-boundary bookkeeping — absorbs it for free as part of each
        block's single boundary sync."""
        state, history, _ = self._dispatch_block(n_steps, n_steps)
        if self._cfg.stop_fitness is None:
            self._gen_host += n_steps  # exact: no freeze possible
        else:
            self._gen_dirty = True  # frozen steps may not have advanced it
        return state, history

    def _dispatch_block(self, n_steps: int, limit: int):
        """One block dispatch: a compiled program of `n_steps` scan steps,
        of which only the first `limit` advance (the rest freeze) — so one
        program serves every ragged boundary ≤ n_steps. No host sync, no
        generation bookkeeping. Returns (state, history, counters) with
        counters the device-resident int32[n_steps, C] telemetry stream
        (repro.obs.counters)."""
        if self.state is None:
            self.init()
        if self._stream is not None:
            raise ValueError("streamed/chunked datasets advance one generation "
                             "per host-driven chunk fold; evolution blocks "
                             "need a device-resident dataset (drive the run "
                             "with evolve() instead)")
        if not self._backend.jittable:
            raise ValueError(f"backend {self._backend.name!r} is host-only; "
                             f"evolution blocks need a jittable backend")
        if self.mesh is not None:
            block_fn = self._block_cache.get(n_steps)
            if block_fn is None:
                block, _ = self.build_sharded_block(n_steps)
                with compat.set_mesh(self.mesh):
                    block_fn = jax.jit(block, donate_argnums=(0,))
                self._block_cache[n_steps] = block_fn
            with compat.set_mesh(self.mesh):
                self.state, history, counters = block_fn(
                    self.state, self._X, self._y, self._weight,
                    jnp.asarray(limit, jnp.int32))
        else:
            self.state, history, counters = engine.evolve_block(
                self._cfg, self.state, self._X, self._y, self._weight,
                jnp.asarray(limit, jnp.int32), n_steps=n_steps)
        self._last_counters = counters
        return self.state, history, counters

    # --- telemetry accounting (repro.obs) ------------------------------------

    def _count_host_sync(self, n: int = 1):
        """THE host-sync accounting point. Every path that synchronizes
        with the device counts through here (the counter once drifted
        across three independent increment sites), and the obs metrics
        registry sees the same number the `stats` pin tests do."""
        self.stats["host_syncs"] += n
        self.metrics.inc("host_syncs", n)

    def _absorb_counters(self, rows):
        """Fold an int32[K, C] telemetry block (repro.obs.counters) into
        `stats` and the metrics registry: cache hits/queries (and the
        derived `cache_hit_rate`), frozen steps, migrations, and tree
        evaluations (× the real row count for trees·rows)."""
        tot = _tc.totals(rows)
        for name, v in tot.items():
            self.stats[name] = self.stats.get(name, 0) + v
            if v:
                self.metrics.inc(name, v)
        self.stats["cache_hit_rate"] = _tc.hit_rate(self.stats)
        self.metrics.gauge("cache_hit_rate", self.stats["cache_hit_rate"])
        if self._n_rows and tot["tree_evals"]:
            # int64 host math — the device stream stays int32-safe
            self.metrics.inc("tree_row_evals", tot["tree_evals"] * self._n_rows)
        self.metrics.emit("counters", **tot)

    def _record_host_eval(self, hit: int, queries: int, evals: int):
        """Host-path twin of the device counter stream: the scalar/stream
        generation loops compute their elite-cache gate on the host, so
        the same telemetry columns land without any device work."""
        if queries:
            self.stats["cache_queries"] += queries
            self.metrics.inc("cache_queries", queries)
        if hit:
            self.stats["cache_hits"] += hit
            self.metrics.inc("cache_hits", hit)
        self.stats["tree_evals"] += evals
        self.metrics.inc("tree_evals", evals)
        self.stats["cache_hit_rate"] = _tc.hit_rate(self.stats)

    def absorb_block_telemetry(self) -> dict:
        """Fold the latest raw `evolve_block()` dispatch's counter stream
        into `stats` (ONE host sync) and return `stats`. `evolve()` does
        this automatically inside each block's boundary sync; this hook
        is for raw-block drivers (benchmarks) that want the cache hit
        rate afterwards."""
        if self._last_counters is not None:
            rows = jax.device_get(self._last_counters)
            self._last_counters = None
            self._count_host_sync()
            self._absorb_counters(rows)
        return self.stats

    def _eval_rows(self, op, arg):
        """Host-side fitness of genome rows [R, N] -> np.f32[R] against the
        session dataset — monolithic (one backend call) or streamed (a
        chunk fold over `self._stream`, finalized once). The streaming
        path composes with a mesh: each chunk is placed with the data-axis
        sharding and folded through the shard_map'd program from
        engine.build_stream_fold, so the reduction semantics match the
        device generation step exactly."""
        cfg = self._cfg
        if self._stream is None:
            return np.asarray(self._backend.fitness(
                np.asarray(op), np.asarray(arg),
                self._X, self._y, np.asarray(cfg.tree_spec.const_table()),
                cfg.tree_spec, cfg.fitness, weight=self._weight,
                data_tile=cfg.data_tile), np.float32)
        kern = fit.get_kernel(cfg.fitness.kernel)
        op, arg = jnp.asarray(op), jnp.asarray(arg)
        if self._stream_fold is not None:
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P

            sh_X = NamedSharding(self.mesh, P(None, "data"))
            sh_y = NamedSharding(self.mesh, P("data"))
            acc = jnp.zeros((op.shape[0], kern.n_moments), jnp.float32)
            with compat.set_mesh(self.mesh):
                for X, y, w in self._stream:
                    # per-chunk host-side cost (place + dispatch; the fold
                    # itself is async) — no sync is added for timing
                    t0 = time.perf_counter()
                    with self.tracer.span("chunk"):
                        acc = self._stream_fold(acc, op, arg,
                                                jax.device_put(X, sh_X),
                                                jax.device_put(y, sh_y),
                                                jax.device_put(w, sh_y))
                    self.metrics.observe("chunk_s", time.perf_counter() - t0)
            fitness = kern.reduce_moments(acc, cfg.fitness)
        else:
            t0 = time.perf_counter()
            with self.tracer.span("stream_fold"):
                fitness = engine.chunked_fitness(cfg, op, arg, self._stream,
                                                 impl=self._backend.name)
            self.metrics.observe("stream_fold_s", time.perf_counter() - t0)
        if self._stream.n_rows is not None:
            self._n_rows = self._stream.n_rows
        return np.asarray(fitness, np.float32)

    def _host_step(self, state: GPState) -> GPState:
        """Generation loop body for non-jittable (host) backends — same
        contract as engine.evolve_step, with evaluation on the host. The
        selection/variation program is jitted ONCE per (spec, mix,
        tourn_size, elitism) and cached across call sites and sessions
        (backends.host_next_generation). Island-batched state loops the
        host evaluator over islands, breeds each with its own operator
        parameters, and applies the in-device migration lowering — the
        scalar baseline runs the same island semantics as the jitted
        paths (per-generation host sync, as ever)."""
        cfg = self._cfg
        if cfg.island.islands > 1:
            return self._host_step_islands(state)
        eval_rows = self._eval_rows

        # host mirror of engine._cached_fitness: exact genome match on the
        # elite head skips its re-evaluation (bitwise-identical — cached
        # fitness IS last generation's evaluation of the same rows)
        E = state.cache_op.shape[0]
        op_h, arg_h = np.asarray(state.op), np.asarray(state.arg)
        hit = E and (np.array_equal(op_h[:E], np.asarray(state.cache_op))
                     and np.array_equal(arg_h[:E], np.asarray(state.cache_arg)))
        self._record_host_eval(int(bool(hit)), 1 if E else 0,
                               op_h.shape[0] - (E if hit else 0))
        if hit:
            fitness = np.concatenate([np.asarray(state.cache_fit),
                                      eval_rows(op_h[E:], arg_h[E:])])
        else:
            fitness = eval_rows(op_h, arg_h)
        i = int(fitness.argmin())
        improved = fitness[i] < float(state.best_fitness)
        best_op = state.op[i] if improved else state.best_op
        best_arg = state.arg[i] if improved else state.best_arg
        best_fit = min(float(fitness[i]), float(state.best_fitness))
        sel = fitness
        if cfg.parsimony:
            sel = fitness + cfg.parsimony * np.asarray(tree_sizes(state.op), np.float32)
        if E:
            # jnp.argsort (stable) — same tie-break order as the jitted
            # next_generation's elite pick, so the cached rows are exactly
            # the elites it will place at [:E] next generation
            best = np.asarray(jnp.argsort(jnp.asarray(sel)))[:E]
            cache = (jnp.asarray(op_h[best]), jnp.asarray(arg_h[best]),
                     jnp.asarray(fitness[best]))
        else:
            cache = (state.cache_op, state.cache_arg, state.cache_fit)
        key, k_next = jax.random.split(state.key)
        next_gen = _backends.host_next_generation(
            cfg.tree_spec, cfg.mix, cfg.tourn_size, cfg.elitism)
        new_op, new_arg = next_gen(k_next, state.op, state.arg, jnp.asarray(sel))
        return GPState(key, new_op, new_arg, jnp.asarray(fitness), best_op, best_arg,
                       jnp.asarray(best_fit, jnp.float32), state.generation + 1,
                       *cache)

    def _host_step_islands(self, state: GPState) -> GPState:
        """Island generation on a host-only backend: evaluate the
        flattened [I·P] population in one backend call, breed per island
        through the cached vmapped selection program, migrate across the
        island axis (islands.migrate_local)."""
        from repro.core import islands as isl

        cfg = self._cfg
        icfg = cfg.island
        I, P, N = state.op.shape
        op2 = np.asarray(state.op).reshape(I * P, N)
        arg2 = np.asarray(state.arg).reshape(I * P, N)
        eval_rows = self._eval_rows

        # one ALL-islands hit gate, mirroring engine._island_step_body
        E = state.cache_op.shape[1]
        op3, arg3 = op2.reshape(I, P, N), arg2.reshape(I, P, N)
        hit = E and (np.array_equal(op3[:, :E], np.asarray(state.cache_op))
                     and np.array_equal(arg3[:, :E], np.asarray(state.cache_arg)))
        self._record_host_eval(int(bool(hit)), 1 if E else 0,
                               I * P - (I * E if hit else 0))
        if hit:
            tail = eval_rows(op3[:, E:].reshape(-1, N),
                             arg3[:, E:].reshape(-1, N)).reshape(I, P - E)
            fitness = np.concatenate([np.asarray(state.cache_fit), tail], axis=1)
        else:
            fitness = eval_rows(op2, arg2).reshape(I, P)
        i_best = fitness.argmin(axis=1)
        rows = np.arange(I)
        cand_fit = fitness[rows, i_best]
        improved = cand_fit < np.asarray(state.best_fitness)
        best_op = jnp.where(improved[:, None], np.asarray(state.op)[rows, i_best],
                            state.best_op)
        best_arg = jnp.where(improved[:, None], np.asarray(state.arg)[rows, i_best],
                             state.best_arg)
        best_fit = jnp.minimum(jnp.asarray(cand_fit), state.best_fitness)
        sel = fitness
        if cfg.parsimony:
            sizes = np.asarray(tree_sizes(jnp.asarray(op2)), np.float32)
            sel = fitness + cfg.parsimony * sizes.reshape(I, P)
        if E:
            best = np.asarray(jnp.argsort(jnp.asarray(sel), axis=-1))[:, :E]
            rows_e = np.arange(I)[:, None]
            cache = (jnp.asarray(op3[rows_e, best]),
                     jnp.asarray(arg3[rows_e, best]),
                     jnp.asarray(fitness[rows_e, best]))
        else:
            cache = (state.cache_op, state.cache_arg, state.cache_fit)
        next_gen = _backends.host_next_generation_islands(
            cfg.tree_spec, icfg, cfg.mix, cfg.tourn_size, cfg.elitism)
        keys, new_op, new_arg = next_gen(state.key, state.op, state.arg,
                                         jnp.asarray(sel))
        if icfg.migrate_k and I > 1:
            e_op, e_arg = isl.island_elites(state.op, state.arg,
                                            jnp.asarray(fitness), icfg.migrate_k)
            new_op, new_arg = isl.migrate_local(
                icfg, new_op, new_arg, e_op, e_arg, state.generation,
                jnp.asarray(cand_fit))
        return GPState(keys, new_op, new_arg, jnp.asarray(fitness), best_op,
                       best_arg, best_fit, state.generation + 1, *cache)

    def _block_span(self, remaining: int) -> int:
        """Block size K = min(checkpoint period, callback period, explicit
        block_size, remaining) — every host-visible side effect lands on a
        block boundary, so larger periods buy longer device residency.
        Periods are PHASE-ALIGNED to the absolute generation counter (the
        next boundary lands ON the period's multiple), so `maybe_save`'s
        `step % every == 0` test and the callback cadence hold no matter
        how earlier blocks, resumes, or early stops offset the counter."""
        k = remaining
        if self._manager is not None:
            every = self._manager.every
            k = min(k, every - self._gen_host % every)
        if self._callback is not None:
            k = min(k, self._callback_every - self._gen_host % self._callback_every)
        if self._block_size is not None:
            k = min(k, self._block_size)
        return max(1, k)

    # frozen steps are branch-free selects, NOT skips — they still run the
    # full evaluation. With stop_fitness armed but no period configured,
    # cap the block so a converged run overshoots at most this many
    # generations of device compute before the host notices.
    _STOP_CHECK_SPAN = 32

    def _block_quantum(self, total: int) -> int:
        """Compiled block-program length: the smallest configured period
        (every `_block_span` is ≤ it), so ONE compiled scan serves every
        boundary — ragged phase-alignment gaps and the final partial block
        run with a dynamic `limit` instead of a fresh compile."""
        periods = [p for p in (
            self._manager.every if self._manager is not None else None,
            self._callback_every if self._callback is not None else None,
            self._block_size) if p is not None]
        if periods:
            return max(1, min(periods))
        if self._cfg.stop_fitness is not None:
            return max(1, min(total, self._STOP_CHECK_SPAN))
        return max(1, total)

    def _resync_gen(self):
        """Re-read the generation counter from the device — needed only
        after raw `evolve_block()` calls under stop_fitness, where frozen
        steps may not have advanced it. One host sync."""
        if self._gen_dirty:
            self._gen_host = int(self.state.generation)
            self._count_host_sync()
            self._gen_dirty = False

    def _evolve_host(self, total: int) -> GPState:
        """Per-generation host loop for non-jittable backends (each
        generation already synchronizes — blocks would buy nothing)."""
        cfg = self._cfg
        for i in range(total):
            # the block monitor wraps EVERY loop path (a host generation
            # is a one-step block), so block_s_ema/stragglers report here
            # too, not just on the jitted block loop
            with self._block_monitor:
                self.step()
            bf = np.asarray(self.state.best_fitness)
            if bf.ndim:  # island run: keep the per-island streams too
                self.island_history.append(bf.copy())
            best = float(bf.min()) if bf.ndim else float(bf)
            self.history.append(best)
            self._count_host_sync()
            if self._manager is not None:
                with self.tracer.span("checkpoint"):
                    self._manager.maybe_save(self.state, self._gen_host)
            stopped = cfg.stop_fitness is not None and best <= cfg.stop_fitness
            if self._callback is not None and (
                    self._gen_host % self._callback_every == 0
                    or stopped or i == total - 1):
                self._callback(self._gen_host - 1, self.state)
            if stopped:
                break
        return self.state

    def evolve(self, generations: int | None = None) -> GPState:
        """Drive `generations` generations (default: config.generations) in
        device-resident blocks: one dispatch AND one host synchronization
        per block. Checkpointing, the callback, history extension and the
        stop_fitness check all happen at block boundaries; within a block,
        early stop is the engine's branch-free on-device freeze — no extra
        host round-trips, and the device-compute overshoot is bounded by
        the block span (_STOP_CHECK_SPAN when only stop_fitness is set)."""
        if self.state is None:
            self.init()
        cfg = self._cfg
        total = generations if generations is not None else cfg.generations
        if not self._backend.jittable or self._stream is not None:
            self._evolve_host(total)
        else:
            self._resync_gen()
            target = self._gen_host + total
            quantum = self._block_quantum(total)
            while self._gen_host < target:
                # K never exceeds the compiled block length: with
                # stop_fitness armed but no period, span = remaining >
                # quantum, and an uncapped K would misread the full
                # block (ran == quantum < K) as an early-stop freeze
                # and silently truncate the run
                K = min(self._block_span(target - self._gen_host), quantum)
                prev_gen = self._gen_host
                block_idx = self.stats["blocks"]
                # the monitor times dispatch THROUGH the block-boundary
                # sync — the span a straggling host/device would stretch
                with self._block_monitor, self.tracer.span(
                        "block", args={"k": K, "quantum": quantum}), \
                        self.tracer.maybe_profile(block_idx):
                    _, history, counters = self._dispatch_block(quantum, K)
                    # ONE sync per block: final generation counter, the
                    # best-fitness stream and the telemetry counter
                    # stream come back together
                    gen_now, hist, crows = jax.device_get(
                        (self.state.generation, history, counters))
                gen_now = int(gen_now)
                self._count_host_sync()
                self._last_counters = None  # absorbed here, same sync
                self._absorb_counters(crows)
                ran = gen_now - prev_gen
                self._gen_host = gen_now
                self.metrics.gauge("generation", gen_now)
                if ran and self._monitor.last:
                    self.metrics.gauge("gens_per_s", ran / self._monitor.last)
                rows = hist[:ran]
                if hist.ndim == 2:  # island run: [K, I] per-island streams
                    self.island_history.extend(np.asarray(rows))
                    rows = rows.min(axis=1)
                self.history.extend(float(b) for b in rows)
                if self._manager is not None:
                    with self.tracer.span("checkpoint"):
                        self._manager.maybe_save(self.state, gen_now)
                stopped = ran < K or (cfg.stop_fitness is not None and ran
                                      and rows[ran - 1] <= cfg.stop_fitness)
                last = stopped or gen_now >= target
                if self._callback is not None and ran and (
                        gen_now % self._callback_every == 0 or last):
                    self._callback(gen_now - 1, self.state)
                if stopped:
                    break
        if self._manager is not None:
            # final save, unless the last block boundary already saved here
            with self.tracer.span("checkpoint"):
                self._manager.wait()
                if (not self._manager.saved_steps
                        or self._manager.saved_steps[-1] != self._gen_host):
                    self._manager.maybe_save(self.state, self._gen_host,
                                             force=True)
                self._manager.wait()
        return self.state

    def fit(self, X, y, *, layout: str = "rows", generations: int | None = None,
            key=None, seeds=None, warm_start: bool = False) -> "GPSession":
        """ingest + init + evolve. With warm_start=True an existing evolved
        state continues on the new data instead of reinitializing."""
        self.ingest(X, y, layout=layout)
        if self.state is None or not warm_start:
            self.init(key=key, seeds=seeds)
        self.evolve(generations)
        return self

    # --- results -------------------------------------------------------------

    def _champion(self) -> tuple[np.ndarray, np.ndarray]:
        """(best_op, best_arg) of the overall champion as host arrays —
        for island runs, the best tree across ALL islands (one sync)."""
        self._require_state()
        best_op, best_arg, bf = jax.device_get(
            (self.state.best_op, self.state.best_arg, self.state.best_fitness))
        if np.ndim(bf):
            i = int(np.argmin(bf))
            best_op, best_arg = best_op[i], best_arg[i]
        return np.asarray(best_op), np.asarray(best_arg)

    def best_expression(self) -> str:
        """The champion tree decoded to an infix string (feature names
        substituted when the session has them) — the best across all
        islands for an island-batched run. Reads best_op/best_arg back
        from the device — one host sync."""
        op, arg = self._champion()
        return to_string(op, arg, feature_names=self.feature_names,
                         const_table=np.asarray(self._cfg.tree_spec.const_table()),
                         genome=self._cfg.tree_spec.genome)

    def island_expressions(self) -> list[str]:
        """Each island's champion decoded to an infix string (a length-1
        list for the classic layout) — one host sync."""
        self._require_state()
        best_op, best_arg = jax.device_get((self.state.best_op,
                                            self.state.best_arg))
        best_op, best_arg = np.atleast_2d(best_op), np.atleast_2d(best_arg)
        consts = np.asarray(self._cfg.tree_spec.const_table())
        return [to_string(o, a, feature_names=self.feature_names,
                          const_table=consts,
                          genome=self._cfg.tree_spec.genome)
                for o, a in zip(best_op, best_arg)]

    def predict(self, X, *, layout: str = "rows") -> np.ndarray:
        """Best tree evaluated on new data via this session's backend:
        X [rows, features] (or [features, rows] with layout='features')
        -> f32[rows] predictions, copied back to the host (one sync).
        Single-device only — prediction is one tree, never worth a mesh."""
        self._require_state()
        X = np.asarray(X, np.float32)
        X_fm = feature_major(X) if layout == "rows" else X
        best_op, best_arg = self._champion()
        preds = self._backend.evaluate(
            jnp.asarray(best_op)[None], jnp.asarray(best_arg)[None],
            jnp.asarray(X_fm), self._cfg.tree_spec.const_table(), self._cfg.tree_spec)
        return np.asarray(preds)[0]

    def score(self, X, y, *, layout: str = "rows") -> float:
        """The fitness kernel's human-facing metric (FitnessKernel.metric)
        of the best tree on (X, y) — fraction correct for classify/match,
        mean |err| for regression, R² for r2 — as a host float (syncs)."""
        preds = self.predict(X, layout=layout)
        metric = fit.get_kernel(self._cfg.fitness.kernel).metric(
            jnp.asarray(preds)[None], jnp.asarray(y, jnp.float32), self._cfg.fitness)
        return float(np.asarray(metric)[0])

    def _require_state(self):
        if self.state is None:
            raise ValueError("session has no evolved state — call fit() first")

    # --- dataset convenience -------------------------------------------------

    @classmethod
    def from_dataset(cls, dataset: str, *, max_rows: int | None = None,
                     config: GPConfig | None = None, **kw) -> "GPSession":
        """Session pre-loaded with one of the paper's datasets (data/
        datasets.py), kernel and function set defaulted from its metadata."""
        from repro.data.datasets import BY_NAME

        X_rows, y, meta = BY_NAME[dataset]()
        if max_rows is not None and X_rows.shape[0] > max_rows:
            X_rows, y = X_rows[:max_rows], y[:max_rows]
        if config is None:
            kw.setdefault("name", f"karoo-{dataset}")
            kw.setdefault("kernel", meta["kernel"])
            if "n_classes" in meta:
                kw.setdefault("n_classes", meta["n_classes"])
            kw.setdefault("fn_set", prim.KITCHEN_SINK if meta["kernel"] == "r"
                          else prim.CLASSIFY_SET)
            kw.setdefault("feature_names", meta.get("features"))
        sess = cls(config, **kw)
        return sess.ingest(X_rows, y)
