"""Expression parser: strings → heap trees (Karoo's "customized seed
populations", §2.2).

Accepts the same grammar `trees.to_string` emits, so round-trips hold:

    expr    := '(' expr op expr ')' | name '(' expr [',' expr] ')'
             | feature | number
    op      := '+' | '-' | '*' | '/'
    feature := 'x' INT | any name in feature_names
    number  := integer/float present in the const table

Seeded trees are validated against the TreeSpec (depth ceiling, feature
count, const table membership) — a seed that can't be represented raises
rather than silently truncating.
"""
from __future__ import annotations

import re

import numpy as np

from repro.core import primitives as prim
from repro.core.trees import TreeSpec

_SYM = {"+": "add", "-": "sub", "*": "mul", "/": "div"}
_TOKEN = re.compile(r"\s*([A-Za-z_]\w*|-?\d+\.?\d*|[(),+\-*/])")


def _tokenize(s: str):
    out, i = [], 0
    while i < len(s):
        m = _TOKEN.match(s, i)
        if not m:
            raise ValueError(f"bad token at ...{s[i:i+12]!r}")
        out.append(m.group(1))
        i = m.end()
    return out


class _Parser:
    def __init__(self, tokens, spec: TreeSpec, feature_names):
        self.t = tokens
        self.i = 0
        self.spec = spec
        self.names = list(feature_names or [])
        self.consts = np.asarray(spec.const_table())

    def peek(self):
        return self.t[self.i] if self.i < len(self.t) else None

    def eat(self, tok=None):
        cur = self.peek()
        if tok is not None and cur != tok:
            raise ValueError(f"expected {tok!r}, got {cur!r}")
        self.i += 1
        return cur

    def parse(self):
        node = self.expr()
        if self.peek() is not None:
            raise ValueError(f"trailing input: {self.t[self.i:]}")
        return node

    def expr(self):
        cur = self.peek()
        if cur == "(":
            self.eat("(")
            lhs = self.expr()
            op = self.eat()
            if op not in _SYM:
                raise ValueError(f"unknown operator {op!r}")
            rhs = self.expr()
            self.eat(")")
            return (prim.opcode_of(_SYM[op]), lhs, rhs)
        if re.fullmatch(r"-?\d+\.?\d*", cur or ""):
            self.eat()
            val = float(cur)
            idx = np.where(np.isclose(self.consts, val))[0]
            if len(idx) == 0:
                raise ValueError(f"constant {val} not in const table {self.consts}")
            return ("const", int(idx[0]))
        name = self.eat()
        if self.peek() == "(":  # function call
            if name not in prim.FN_NAMES:
                raise ValueError(f"unknown function {name!r}")
            self.eat("(")
            a = self.expr()
            b = None
            if self.peek() == ",":
                self.eat(",")
                b = self.expr()
            self.eat(")")
            code = prim.opcode_of(name)
            arity = prim.ARITY[code]
            if (b is None) != (arity == 1):
                raise ValueError(f"{name} expects arity {arity}")
            return (code, a, b)
        # terminal feature
        if name in self.names:
            return ("feat", self.names.index(name))
        m = re.fullmatch(r"x(\d+)", name)
        if m and int(m.group(1)) < self.spec.n_features:
            return ("feat", int(m.group(1)))
        raise ValueError(f"unknown terminal {name!r}")


def _fill(node, op, arg, idx, spec):
    if idx >= spec.num_nodes:
        raise ValueError(f"expression deeper than max_depth={spec.max_depth}")
    if node[0] == "feat":
        op[idx], arg[idx] = prim.FEATURE, node[1]
    elif node[0] == "const":
        op[idx], arg[idx] = prim.CONST, node[1]
    else:
        code, a, b = node
        op[idx] = code
        _fill(a, op, arg, 2 * idx + 1, spec)
        if b is not None:
            _fill(b, op, arg, 2 * idx + 2, spec)


def _emit_postfix(node, out):
    """Postorder walk → list of (op, arg) instructions. Emitting directly
    (not via a heap) keeps deep-but-narrow expressions parseable: postfix
    genomes are bounded by instruction count and operand-stack depth, not
    by the heap's depth ceiling."""
    if node[0] == "feat":
        out.append((prim.FEATURE, node[1]))
    elif node[0] == "const":
        out.append((prim.CONST, node[1]))
    else:
        code, a, b = node
        _emit_postfix(a, out)
        if b is not None:
            _emit_postfix(b, out)
        out.append((code, 0))


def parse_tree(expr: str, spec: TreeSpec, feature_names=None):
    """One expression string → (op, arg) int32 rows of length num_nodes,
    in the spec's genome form."""
    node = _Parser(_tokenize(expr), spec, feature_names).parse()
    op = np.zeros(spec.num_nodes, np.int32)
    arg = np.zeros(spec.num_nodes, np.int32)
    if spec.genome == "postfix":
        prog: list = []
        _emit_postfix(node, prog)
        if len(prog) > spec.num_nodes:
            raise ValueError(f"expression has {len(prog)} nodes; postfix "
                             f"genomes hold at most {spec.num_nodes}")
        depth = 0
        for code, _ in prog:
            depth += 1 - int(prim.ARITY[code])
            if depth > spec.stack_size:
                raise ValueError(
                    f"expression needs operand-stack depth {depth} > "
                    f"stack_size={spec.stack_size} (P5)")
        for t, (code, a) in enumerate(prog):
            op[t], arg[t] = code, a
        return op, arg
    _fill(node, op, arg, 0, spec)
    return op, arg


def seed_population(exprs, spec: TreeSpec, pop_size: int, key,
                    feature_names=None):
    """Seed the first len(exprs) slots with parsed trees; fill the rest
    with a ramped random population (Karoo's seed-population semantics)."""
    import jax.numpy as jnp

    from repro.core.trees import generate_population

    if len(exprs) > pop_size:
        raise ValueError("more seeds than population slots")
    op, arg = generate_population(key, pop_size, spec)
    op, arg = np.array(op), np.array(arg)  # writable host copies
    for i, e in enumerate(exprs):
        op[i], arg[i] = parse_tree(e, spec, feature_names)
    return jnp.asarray(op), jnp.asarray(arg)
