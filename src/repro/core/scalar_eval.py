"""Paper-faithful scalar baseline — the "1-CPU_SP" configuration.

Karoo GP v0.9.1.6 evaluated each tree's SymPy expression once *per data
point* (scalar substitution), which is the slow baseline every figure in
the paper compares against. This module reproduces that execution model:
a recursive Python interpreter applied row by row, no vectorization, no
jit. It exists so benchmarks/ can measure the same scalar-vs-vector axis
the paper measures (Figs 1–3: 2x, 15x, 875x).
"""
from __future__ import annotations

import math

import numpy as np

from repro.core import primitives as prim

_EPS = 1e-9


def _apply(name: str, a, b):
    """One primitive in true float32 arithmetic (np.float32 operands stay
    f32 through + - * /), so protected-op branch decisions are bit-identical
    to the vectorized engine's."""
    f32 = np.float32
    a = f32(a)
    b = f32(b)
    if name == "add":
        return a + b
    if name == "sub":
        return a - b
    if name == "mul":
        return a * b
    if name == "div":
        return f32(1.0) if abs(b) < f32(_EPS) else a / b
    if name == "neg":
        return -a
    if name == "abs":
        return abs(a)
    if name == "sin":
        return f32(math.sin(a))
    if name == "cos":
        return f32(math.cos(a))
    if name == "sqrt":
        return f32(math.sqrt(abs(a)))
    if name == "log":
        return f32(math.log(abs(a) + f32(_EPS)))
    if name == "square":
        return a * a
    if name == "min":
        return min(a, b)
    if name == "max":
        return max(a, b)
    raise ValueError(name)


def eval_tree_scalar(op_row, arg_row, row, const_table, idx: int = 0) -> float:
    """Evaluate one heap tree on ONE data row, recursively (the baseline).

    Intermediates are rounded to float32 at every node so the baseline is
    numerically faithful to the vectorized engine (Karoo's TF ops are f32;
    comparing f64-vs-f32 interpreters would otherwise diverge around the
    protected-division threshold)."""
    o = int(op_row[idx])
    if o == prim.EMPTY:
        return 0.0
    if o == prim.CONST:
        return float(np.float32(const_table[int(arg_row[idx])]))
    if o == prim.FEATURE:
        return float(np.float32(row[int(arg_row[idx])]))
    p = prim.FUNCTIONS[o - 3]
    a = eval_tree_scalar(op_row, arg_row, row, const_table, 2 * idx + 1)
    b = eval_tree_scalar(op_row, arg_row, row, const_table, 2 * idx + 2) if p.arity == 2 else 0.0
    return float(np.float32(_apply(p.name, a, b)))


def eval_postfix_scalar(op_row, arg_row, row, const_table) -> float:
    """Evaluate one postfix stream on ONE data row with a list stack —
    the scalar oracle for the linear-genome interpreters. Same f32
    rounding discipline per node as `eval_tree_scalar`."""
    stack: list[float] = []
    for t in range(len(op_row)):
        o = int(op_row[t])
        if o == prim.EMPTY:
            break
        if o == prim.CONST:
            stack.append(float(np.float32(const_table[int(arg_row[t])])))
        elif o == prim.FEATURE:
            stack.append(float(np.float32(row[int(arg_row[t])])))
        else:
            p = prim.FUNCTIONS[o - 3]
            if p.arity == 1:
                a, b = stack.pop(), 0.0
            else:
                b = stack.pop()
                a = stack.pop()
            stack.append(float(np.float32(_apply(p.name, a, b))))
    return stack[0] if stack else 0.0


def evaluate_population_scalar(op, arg, X_rows, const_table,
                               genome: str = "tree") -> np.ndarray:
    """preds[p, d] via per-tree, per-row recursion. X_rows: [D, F] row-major
    (the paper's Eq. 1 layout — the un-transposed original)."""
    op = np.asarray(op)
    arg = np.asarray(arg)
    X_rows = np.asarray(X_rows)
    const_table = np.asarray(const_table)
    P, D = op.shape[0], X_rows.shape[0]
    one = eval_postfix_scalar if genome == "postfix" else eval_tree_scalar
    out = np.empty((P, D), np.float32)
    for p in range(P):
        for d in range(D):
            out[p, d] = one(op[p], arg[p], X_rows[d], const_table)
    return out


def fitness_scalar(op, arg, X_rows, y, const_table, kernel: str = "r",
                   n_classes: int = 3, precision: float = 1e-4,
                   weight=None, genome: str = "tree") -> np.ndarray:
    """Scalar-evaluated predictions reduced by the registered FitnessKernel
    (the reduction is negligible next to the per-point interpreter; sharing
    the kernel registry keeps the NaN semantics identical across paths).
    `weight` masks dataset-padding rows (0.0 = padded), same convention as
    the vectorized paths."""
    from repro.core.fitness import FitnessSpec, fitness_from_preds

    preds = evaluate_population_scalar(op, arg, X_rows, const_table,
                                       genome=genome)
    spec = FitnessSpec(kernel, n_classes=n_classes, precision=precision)
    w = None if weight is None else np.asarray(weight, np.float32)
    return np.asarray(fitness_from_preds(preds, np.asarray(y, np.float32), spec,
                                         weight=w))
