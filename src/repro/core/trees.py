"""Heap-tensor GP tree representation + ramped half-and-half generation.

A population is a pair of integer tensors:

    op  : int32[pop, NODES]   opcode per heap slot (see primitives)
    arg : int32[pop, NODES]   feature index (FEATURE) or const index (CONST)

NODES = 2**(max_depth+1) - 1 — a complete binary heap: node ``i`` has
children ``2i+1``/``2i+2`` and depth ``floor(log2(i+1))``. The paper's
``tree depth max = 5`` becomes NODES = 63. This encoding is the central
TPU adaptation: the whole population is evaluated by one static,
level-synchronous program (no per-tree graphs, no recompilation).

Well-formedness invariants (preserved by generation and by every genetic
operator in evolve.py):
  I1  slot 0 (root) is never EMPTY;
  I2  a binary-function slot has both children non-EMPTY; a unary slot has
      a non-EMPTY left child and an EMPTY right child;
  I3  terminal (CONST/FEATURE) and EMPTY slots have EMPTY children;
  I4  slots at max depth hold terminals only.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import primitives as prim

# --- static index tables ----------------------------------------------------


def n_nodes(max_depth: int) -> int:
    return 2 ** (max_depth + 1) - 1


def depth_table(num_nodes: int) -> np.ndarray:
    """DEPTH[i] = depth of heap slot i."""
    return np.floor(np.log2(np.arange(num_nodes) + 1)).astype(np.int32)


def subtree_mask_table(num_nodes: int) -> np.ndarray:
    """MASK[i, j] = True iff j is i or a descendant of i."""
    depth = depth_table(num_nodes)
    i = np.arange(num_nodes)[:, None] + 1  # 1-based
    j = np.arange(num_nodes)[None, :] + 1
    k = depth[None, :] - depth[:, None]  # relative depth of j under i
    anc = np.where(k >= 0, j >> np.maximum(k, 0), -1)
    return (anc == i) & (k >= 0)


# --- generation spec ---------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TreeSpec:
    """Static parameters of a tree population (hashable for jit)."""

    max_depth: int = 5
    n_features: int = 2
    n_consts: int = 8
    fn_set: prim.FunctionSet = prim.ARITHMETIC
    p_const: float = 0.2  # probability a terminal is a constant
    grow_p_fn: float = 0.6  # probability an internal slot is a function (grow)

    def __hash__(self):
        return hash((self.max_depth, self.n_features, self.n_consts,
                     tuple(self.fn_set.opcodes.tolist()), self.p_const, self.grow_p_fn))

    def __eq__(self, other):
        return isinstance(other, TreeSpec) and hash(self) == hash(other)

    @property
    def num_nodes(self) -> int:
        return n_nodes(self.max_depth)

    def const_table(self) -> jnp.ndarray:
        # Karoo-style integer constant terminals, symmetric around zero.
        half = self.n_consts // 2
        return jnp.asarray(
            np.concatenate([np.arange(1, half + 1), -np.arange(1, self.n_consts - half + 1)]).astype(np.float32)
        )


# --- random draws ------------------------------------------------------------


def _draw_terminal(key, shape, spec: TreeSpec):
    """Random terminal (op, arg) arrays of `shape`."""
    k1, k2, k3 = jax.random.split(key, 3)
    is_const = jax.random.bernoulli(k1, spec.p_const, shape)
    op = jnp.where(is_const, prim.CONST, prim.FEATURE)
    feat = jax.random.randint(k2, shape, 0, spec.n_features)
    cons = jax.random.randint(k3, shape, 0, spec.n_consts)
    return op.astype(jnp.int32), jnp.where(is_const, cons, feat).astype(jnp.int32)


def _draw_function(key, shape, spec: TreeSpec, binary_only: bool = False):
    """Random function opcode drawn from the spec's function set."""
    ops = spec.fn_set.binary_opcodes if binary_only else np.asarray(spec.fn_set.opcodes)
    idx = jax.random.randint(key, shape, 0, len(ops))
    return jnp.asarray(ops)[idx].astype(jnp.int32)


@partial(jax.jit, static_argnames=("spec", "pop"))
def generate_population(key, pop: int, spec: TreeSpec):
    """Ramped half-and-half initial population (Karoo's `(r)amped` type).

    Trees are assigned a ramp depth in [1, max_depth] and a method
    (full | grow), then generated top-down level by level, vectorized
    over [pop, level_width]. Returns (op, arg): int32[pop, NODES].
    """
    N = spec.num_nodes
    D = spec.max_depth
    kd, km, kt = jax.random.split(key, 3)
    ramp_depth = jax.random.randint(kd, (pop,), 1, D + 1)  # per-tree depth ceiling
    full = jax.random.bernoulli(km, 0.5, (pop,))  # full vs grow

    op = jnp.zeros((pop, N), jnp.int32)
    arg = jnp.zeros((pop, N), jnp.int32)
    active = jnp.zeros((pop, N), jnp.bool_).at[:, 0].set(True)

    DEPTH = jnp.asarray(depth_table(N))
    keys = jax.random.split(kt, D + 1)
    for d in range(D + 1):
        lo, w = 2**d - 1, 2**d
        kf, kg, kterm, kchoice = jax.random.split(keys[d], 4)
        at_ceiling = (d >= ramp_depth)[:, None]  # [pop, 1]
        # choose: function or terminal for the active slots at this level
        want_fn = jnp.where(
            full[:, None], ~at_ceiling,
            ~at_ceiling & jax.random.bernoulli(kg, spec.grow_p_fn, (pop, w)),
        )
        # Karoo's min 3 nodes: root of any depth>=1 tree is a function.
        if d == 0:
            want_fn = jnp.ones_like(want_fn)
        fn_op = _draw_function(kf, (pop, w), spec, binary_only=(d == 0))
        t_op, t_arg = _draw_terminal(kterm, (pop, w), spec)
        lvl_active = active[:, lo:lo + w]
        lvl_op = jnp.where(want_fn, fn_op, t_op)
        lvl_arg = jnp.where(want_fn, jnp.zeros_like(t_arg), t_arg)
        lvl_op = jnp.where(lvl_active, lvl_op, prim.EMPTY)
        lvl_arg = jnp.where(lvl_active, lvl_arg, 0)
        op = jax.lax.dynamic_update_slice(op, lvl_op, (0, lo))
        arg = jax.lax.dynamic_update_slice(arg, lvl_arg, (0, lo))
        # activate children
        if d < D:
            arity = jnp.asarray(prim.ARITY)[lvl_op]
            l_act = lvl_active & (arity >= 1)
            r_act = lvl_active & (arity == 2)
            child = jnp.stack([l_act, r_act], axis=-1).reshape(pop, 2 * w)
            active = jax.lax.dynamic_update_slice(active, child, (0, 2 * w - 1))
    return op, arg


# --- host-side pretty printing (archive/display, like fx_display_) ----------


def to_string(op_row, arg_row, feature_names=None, const_table=None, idx: int = 0) -> str:
    """Render one heap tree as an infix expression string (host-side)."""
    op_row = np.asarray(op_row)
    arg_row = np.asarray(arg_row)
    o = int(op_row[idx])
    if o == prim.EMPTY:
        return "∅"
    if o == prim.CONST:
        c = float(const_table[arg_row[idx]]) if const_table is not None else arg_row[idx]
        return f"{c:g}" if isinstance(c, float) else f"c{arg_row[idx]}"
    if o == prim.FEATURE:
        return feature_names[arg_row[idx]] if feature_names else f"x{arg_row[idx]}"
    p = prim.FUNCTIONS[o - 3]
    lhs = to_string(op_row, arg_row, feature_names, const_table, 2 * idx + 1)
    if p.arity == 1:
        return f"{p.name}({lhs})"
    rhs = to_string(op_row, arg_row, feature_names, const_table, 2 * idx + 2)
    sym = {"add": "+", "sub": "-", "mul": "*", "div": "/"}.get(p.name)
    return f"({lhs} {sym} {rhs})" if sym else f"{p.name}({lhs}, {rhs})"


def tree_sizes(op) -> jnp.ndarray:
    """Number of non-EMPTY nodes per tree."""
    return (op != prim.EMPTY).sum(-1)


def check_invariants(op: np.ndarray, spec: TreeSpec) -> None:
    """Assert well-formedness I1–I4 (host-side, used by tests)."""
    op = np.asarray(op)
    N = spec.num_nodes
    depth = depth_table(N)
    arity = prim.ARITY[op]
    assert (op[:, 0] != prim.EMPTY).all(), "I1: empty root"
    for i in range((N - 1) // 2):
        l, r = op[:, 2 * i + 1], op[:, 2 * i + 2]
        a = arity[:, i]
        assert ((a < 1) | (l != prim.EMPTY)).all(), f"I2: missing left child of {i}"
        assert ((a < 2) | (r != prim.EMPTY)).all(), f"I2: missing right child of {i}"
        assert ((a == 2) | (r == prim.EMPTY)).all(), f"I2/I3: stray right child of {i}"
        assert ((a >= 1) | (l == prim.EMPTY)).all(), f"I3: stray left child of {i}"
    leaf = depth == spec.max_depth
    assert (prim.ARITY[op[:, leaf]] == 0).all(), "I4: function at max depth"
