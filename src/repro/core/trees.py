"""GP genome representations + ramped half-and-half generation.

A population is a pair of integer tensors:

    op  : int32[pop, NODES]   opcode per slot (see primitives)
    arg : int32[pop, NODES]   feature index (FEATURE) or const index (CONST)

with NODES = 2**(max_depth+1) - 1, read in one of TWO forms selected by
``TreeSpec.genome``:

``genome="tree"`` — heap-tensor prefix trees (the original encoding):
node ``i`` has children ``2i+1``/``2i+2`` and depth ``floor(log2(i+1))``.
The paper's ``tree depth max = 5`` becomes NODES = 63. This encoding is
the central TPU adaptation: the whole population is evaluated by one
static, level-synchronous program (no per-tree graphs, no recompile).

Well-formedness invariants (preserved by generation and by every genetic
operator in evolve.py):
  I1  slot 0 (root) is never EMPTY;
  I2  a binary-function slot has both children non-EMPTY; a unary slot has
      a non-EMPTY left child and an EMPTY right child;
  I3  terminal (CONST/FEATURE) and EMPTY slots have EMPTY children;
  I4  slots at max depth hold terminals only.

``genome="postfix"`` — linear postfix genomes (arXiv:2110.11226 /
EvoGP-style): the same ``int32[pop, NODES]`` buffers hold a postfix
instruction stream per row — terminals push, functions pop their
operands and push the result — padded with EMPTY after the program's
active length. Same shapes, so GPState/checkpoints/islands/service
layouts carry either form; crossover and branch mutation become array
splicing (evolve.py) and evaluation becomes a single stack-machine walk
(core/eval.py jnp reference, kernels/gp_eval.py Pallas kernel).

Postfix invariants (P1–P5, checked by `check_invariants`):
  P1  the active program is a contiguous non-EMPTY prefix (length ≥ 1);
  P2  the first instruction is a terminal;
  P3  running stack depth S(t) = cumsum(1 - arity) stays ≥ 1 on every
      active prefix (operands exist when a function executes);
  P4  S(len-1) == 1 (exactly one result remains);
  P5  max S(t) ≤ TreeSpec.stack_size (the operand stack the interpreters
      commit to — max_depth + 1, enough for any depth-ceiling tree).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import primitives as prim

# --- static index tables ----------------------------------------------------


def n_nodes(max_depth: int) -> int:
    return 2 ** (max_depth + 1) - 1


def depth_table(num_nodes: int) -> np.ndarray:
    """DEPTH[i] = depth of heap slot i."""
    return np.floor(np.log2(np.arange(num_nodes) + 1)).astype(np.int32)


def subtree_mask_table(num_nodes: int) -> np.ndarray:
    """MASK[i, j] = True iff j is i or a descendant of i."""
    depth = depth_table(num_nodes)
    i = np.arange(num_nodes)[:, None] + 1  # 1-based
    j = np.arange(num_nodes)[None, :] + 1
    k = depth[None, :] - depth[:, None]  # relative depth of j under i
    anc = np.where(k >= 0, j >> np.maximum(k, 0), -1)
    return (anc == i) & (k >= 0)


# --- generation spec ---------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TreeSpec:
    """Static parameters of a tree population (hashable for jit).

    `genome` selects the population encoding: "tree" (heap prefix trees,
    the parity oracle) or "postfix" (linear postfix genomes evaluated by
    the stack interpreters). Both live in the same int32[P, NODES]
    buffers, so every state/checkpoint/mesh layout is encoding-agnostic.
    """

    max_depth: int = 5
    n_features: int = 2
    n_consts: int = 8
    fn_set: prim.FunctionSet = prim.ARITHMETIC
    p_const: float = 0.2  # probability a terminal is a constant
    grow_p_fn: float = 0.6  # probability an internal slot is a function (grow)
    genome: str = "tree"  # "tree" | "postfix"

    def __post_init__(self):
        if self.genome not in ("tree", "postfix"):
            raise ValueError(f"genome must be 'tree' or 'postfix', "
                             f"got {self.genome!r}")

    def __hash__(self):
        return hash((self.max_depth, self.n_features, self.n_consts,
                     tuple(self.fn_set.opcodes.tolist()), self.p_const,
                     self.grow_p_fn, self.genome))

    def __eq__(self, other):
        return isinstance(other, TreeSpec) and hash(self) == hash(other)

    @property
    def num_nodes(self) -> int:
        return n_nodes(self.max_depth)

    @property
    def stack_size(self) -> int:
        """Operand-stack bound the postfix interpreters commit to. Postorder
        evaluation of any tree within the depth ceiling needs at most
        max_depth + 1 live operands; splice operators reject offspring that
        would exceed it (evolve.py), so the bound is an invariant (P5)."""
        return self.max_depth + 1

    def const_table(self) -> jnp.ndarray:
        # Karoo-style integer constant terminals, symmetric around zero.
        half = self.n_consts // 2
        return jnp.asarray(
            np.concatenate([np.arange(1, half + 1), -np.arange(1, self.n_consts - half + 1)]).astype(np.float32)
        )


# --- random draws ------------------------------------------------------------


def _draw_terminal(key, shape, spec: TreeSpec):
    """Random terminal (op, arg) arrays of `shape`."""
    k1, k2, k3 = jax.random.split(key, 3)
    is_const = jax.random.bernoulli(k1, spec.p_const, shape)
    op = jnp.where(is_const, prim.CONST, prim.FEATURE)
    feat = jax.random.randint(k2, shape, 0, spec.n_features)
    cons = jax.random.randint(k3, shape, 0, spec.n_consts)
    return op.astype(jnp.int32), jnp.where(is_const, cons, feat).astype(jnp.int32)


def _draw_function(key, shape, spec: TreeSpec, binary_only: bool = False):
    """Random function opcode drawn from the spec's function set."""
    ops = spec.fn_set.binary_opcodes if binary_only else np.asarray(spec.fn_set.opcodes)
    idx = jax.random.randint(key, shape, 0, len(ops))
    return jnp.asarray(ops)[idx].astype(jnp.int32)


@partial(jax.jit, static_argnames=("spec", "pop"))
def generate_population(key, pop: int, spec: TreeSpec):
    """Ramped half-and-half initial population (Karoo's `(r)amped` type).

    Trees are assigned a ramp depth in [1, max_depth] and a method
    (full | grow), then generated top-down level by level, vectorized
    over [pop, level_width]. Returns (op, arg): int32[pop, NODES] — in
    the spec's genome form (heap layout, converted to postfix streams
    when spec.genome == "postfix"; the draw itself is identical, so both
    forms sample the same tree distribution from the same key).
    """
    N = spec.num_nodes
    D = spec.max_depth
    kd, km, kt = jax.random.split(key, 3)
    ramp_depth = jax.random.randint(kd, (pop,), 1, D + 1)  # per-tree depth ceiling
    full = jax.random.bernoulli(km, 0.5, (pop,))  # full vs grow

    op = jnp.zeros((pop, N), jnp.int32)
    arg = jnp.zeros((pop, N), jnp.int32)
    active = jnp.zeros((pop, N), jnp.bool_).at[:, 0].set(True)

    DEPTH = jnp.asarray(depth_table(N))
    keys = jax.random.split(kt, D + 1)
    for d in range(D + 1):
        lo, w = 2**d - 1, 2**d
        kf, kg, kterm, kchoice = jax.random.split(keys[d], 4)
        at_ceiling = (d >= ramp_depth)[:, None]  # [pop, 1]
        # choose: function or terminal for the active slots at this level
        want_fn = jnp.where(
            full[:, None], ~at_ceiling,
            ~at_ceiling & jax.random.bernoulli(kg, spec.grow_p_fn, (pop, w)),
        )
        # Karoo's min 3 nodes: root of any depth>=1 tree is a function.
        if d == 0:
            want_fn = jnp.ones_like(want_fn)
        fn_op = _draw_function(kf, (pop, w), spec, binary_only=(d == 0))
        t_op, t_arg = _draw_terminal(kterm, (pop, w), spec)
        lvl_active = active[:, lo:lo + w]
        lvl_op = jnp.where(want_fn, fn_op, t_op)
        lvl_arg = jnp.where(want_fn, jnp.zeros_like(t_arg), t_arg)
        lvl_op = jnp.where(lvl_active, lvl_op, prim.EMPTY)
        lvl_arg = jnp.where(lvl_active, lvl_arg, 0)
        op = jax.lax.dynamic_update_slice(op, lvl_op, (0, lo))
        arg = jax.lax.dynamic_update_slice(arg, lvl_arg, (0, lo))
        # activate children
        if d < D:
            arity = jnp.asarray(prim.ARITY)[lvl_op]
            l_act = lvl_active & (arity >= 1)
            r_act = lvl_active & (arity == 2)
            child = jnp.stack([l_act, r_act], axis=-1).reshape(pop, 2 * w)
            active = jax.lax.dynamic_update_slice(active, child, (0, 2 * w - 1))
    if spec.genome == "postfix":
        return heap_to_postfix(op, arg)
    return op, arg


# --- postfix linear genomes ---------------------------------------------------


def postorder_table(num_nodes: int) -> np.ndarray:
    """PO[i] = postorder rank of heap slot i over the FULL complete heap.

    Pruned trees restrict to their active slots: pruning removes whole
    subtrees, so the relative postorder of the surviving nodes is exactly
    the full-heap postorder filtered to them — which is what
    `heap_to_postfix` exploits to convert with one static permutation."""
    pos = np.zeros(num_nodes, np.int32)
    counter = [0]

    def visit(i):
        if i >= num_nodes:
            return
        visit(2 * i + 1)
        visit(2 * i + 2)
        pos[i] = counter[0]
        counter[0] += 1

    visit(0)
    return pos


def heap_to_postfix(op, arg):
    """Heap populations → postfix streams, jittable, any leading dims.

    Per row: permute slots into full-heap postorder, then compact the
    non-EMPTY entries to the front (rank = running count of active
    slots); the EMPTY tail pads to NODES. int32[..., N] → int32[..., N].
    """
    op = jnp.asarray(op)
    arg = jnp.asarray(arg)
    N = op.shape[-1]
    perm = jnp.asarray(np.argsort(postorder_table(N)))

    def one(op_row, arg_row):
        op_po = op_row[perm]
        arg_po = arg_row[perm]
        active = op_po != prim.EMPTY
        rank = jnp.where(active, jnp.cumsum(active) - 1, N)
        out_op = jnp.zeros((N,), jnp.int32).at[rank].set(op_po, mode="drop")
        out_arg = jnp.zeros((N,), jnp.int32).at[rank].set(arg_po, mode="drop")
        return out_op, out_arg

    lead = op.shape[:-1]
    out_op, out_arg = jax.vmap(one)(op.reshape(-1, N), arg.reshape(-1, N))
    return out_op.reshape(*lead, N), out_arg.reshape(*lead, N)


def postfix_to_heap(op, arg, spec: TreeSpec):
    """Postfix populations → heap trees (host-side; tests/parity oracle).

    Raises ValueError on malformed streams or programs too deep for the
    heap's max_depth ceiling (spliced postfix genomes may legally exceed
    it — only depth-bounded programs round-trip)."""
    op = np.asarray(op).reshape(-1, np.asarray(op).shape[-1])
    arg = np.asarray(arg).reshape(-1, op.shape[-1])
    P, N = op.shape
    out_op = np.zeros((P, N), np.int32)
    out_arg = np.zeros((P, N), np.int32)
    for p in range(P):
        stack = []
        for t in range(N):
            o = int(op[p, t])
            if o == prim.EMPTY:
                break
            a = int(prim.ARITY[o])
            if a == 0:
                stack.append((o, int(arg[p, t]), None, None))
            elif a == 1:
                if not stack:
                    raise ValueError(f"row {p}: unary op at {t} with empty stack")
                c = stack.pop()
                stack.append((o, 0, c, None))
            else:
                if len(stack) < 2:
                    raise ValueError(f"row {p}: binary op at {t} underflows")
                r = stack.pop()
                l_ = stack.pop()
                stack.append((o, 0, l_, r))
        if len(stack) != 1:
            raise ValueError(f"row {p}: postfix stream leaves {len(stack)} "
                             f"values on the stack (want 1)")

        def place(node, idx):
            if idx >= N:
                raise ValueError(f"row {p}: program deeper than "
                                 f"max_depth={spec.max_depth}; it has no heap "
                                 f"form (postfix-only genome)")
            o, a, l_, r = node
            out_op[p, idx] = o
            out_arg[p, idx] = a
            if l_ is not None:
                place(l_, 2 * idx + 1)
            if r is not None:
                place(r, 2 * idx + 2)

        place(stack[0], 0)
    return out_op, out_arg


def postfix_stack_depths(op) -> jnp.ndarray:
    """S int32[..., N]: running operand-stack depth AFTER each instruction
    (cumsum of 1 - arity). Only meaningful on the active prefix — EMPTY
    slots contribute +1 each, so mask with (op != EMPTY) before use."""
    ar = jnp.asarray(prim.ARITY)[jnp.asarray(op)]
    return jnp.cumsum(1 - ar, axis=-1).astype(jnp.int32)


def subtree_spans(op) -> jnp.ndarray:
    """start int32[..., N]: for each position i, the index where the
    subtree (complete subexpression) ENDING at i begins.

    In postfix, the subexpression ending at i starts right after the last
    t < i whose running depth S(t) is strictly below S(i) (no such t →
    0). O(N²) masked max per row — cheap at N = 63. Values beyond a
    row's active length are garbage; callers only index active slots."""
    op = jnp.asarray(op)
    N = op.shape[-1]
    S = postfix_stack_depths(op)
    t = jnp.arange(N, dtype=jnp.int32)
    below = (t[..., None, :] < t[..., :, None]) & (S[..., None, :] < S[..., :, None])
    last = jnp.max(jnp.where(below, t[..., None, :], -1), axis=-1)
    return (last + 1).astype(jnp.int32)


def postfix_lhs_index(op) -> jnp.ndarray:
    """lhs int32[..., N]: for a binary function at position i, the index of
    its LEFT operand's result — start(i-1) - 1, because the right operand
    is always the result of i-1. Garbage (clipped ≥ -1) on non-binary
    slots; the stack kernel only reads it under the binary predicate."""
    start = subtree_spans(op)
    lhs = jnp.concatenate(
        [jnp.zeros_like(start[..., :1]), start[..., :-1] - 1], axis=-1)
    return lhs


# --- subexpression signatures (population-wide dedup, core/eval.py) ----------


def signature_geometry(spec: TreeSpec, num_nodes: int) -> tuple[int, int, int]:
    """(bits, per_word, n_words) of the packed subtree signature.

    A subexpression's canonical form is its postfix token stream with
    terminal arguments embedded: token code = 1 + op·K + arg (arg only
    for terminals; K = max(n_features, n_consts) so FEATURE/CONST args
    never collide across opcodes), 0 reserved for "no token". Codes are
    < 2**bits, so packing `per_word = 30 // bits` codes per int32 word
    (top bits unused — no sign-bit surprises) is injective: equal packed
    words ⟺ equal token streams ⟺ the same subexpression, because
    postfix with known arities parses unambiguously and active codes are
    ≥ 1 (zero-padding cannot alias a shorter stream onto a longer one)."""
    K = max(spec.n_features, spec.n_consts, 1)
    bits = (prim.N_OPCODES * K).bit_length()
    per_word = 30 // bits
    if per_word < 1:
        raise ValueError(
            f"subexpression signatures need token codes ≤ 30 bits; "
            f"n_features/n_consts = {spec.n_features}/{spec.n_consts} "
            f"needs {bits}")
    n_words = -(-num_nodes // per_word)
    return bits, per_word, n_words


def subtree_signatures(op, arg, spec: TreeSpec) -> jnp.ndarray:
    """int32[P, N, W] packed canonical signature of the subexpression
    ENDING at every position of every postfix row (W from
    `signature_geometry`). Two positions — in the same row or across the
    whole population — carry the identical signature iff they end the
    identical subexpression. Inactive (EMPTY) positions get the all-zero
    signature, which no active subexpression can produce. This is the
    device-side canonicalization step of the population-wide dedup layer
    (core/eval.build_dedup_plan)."""
    op = jnp.asarray(op)
    arg = jnp.asarray(arg)
    P, N = op.shape
    bits, per_word, W = signature_geometry(spec, N)
    K = max(spec.n_features, spec.n_consts, 1)

    ar = jnp.asarray(prim.ARITY)[op]
    active = op != prim.EMPTY
    code = jnp.where(active,
                     1 + op * K + jnp.where(ar == 0, jnp.clip(arg, 0, K - 1), 0),
                     0).astype(jnp.int32)
    start = subtree_spans(op)
    length = jnp.arange(N, dtype=jnp.int32)[None, :] - start + 1

    t = jnp.arange(N, dtype=jnp.int32)

    def one(code_row, start_row, len_row, act_row):
        idx = start_row[:, None] + t[None, :]  # [N, N] span positions
        g = code_row[jnp.clip(idx, 0, N - 1)]
        mask = (t[None, :] < len_row[:, None]) & act_row[:, None]
        return jnp.where(mask, g, 0)

    sig = jax.vmap(one)(code, start, length, active)  # [P, N, N]
    pad = W * per_word - N
    if pad:
        sig = jnp.pad(sig, ((0, 0), (0, 0), (0, pad)))
    sig = sig.reshape(P, N, W, per_word)
    shifts = (jnp.arange(per_word, dtype=jnp.int32) * bits)
    return jnp.sum(sig << shifts[None, None, None, :], axis=-1,
                   dtype=jnp.int32)


# --- host-side pretty printing (archive/display, like fx_display_) ----------


_INFIX_SYM = {"add": "+", "sub": "-", "mul": "*", "div": "/"}


def _terminal_str(o, a, feature_names, const_table) -> str:
    if o == prim.CONST:
        c = float(const_table[a]) if const_table is not None else a
        return f"{c:g}" if isinstance(c, float) else f"c{a}"
    return feature_names[a] if feature_names else f"x{a}"


def to_string(op_row, arg_row, feature_names=None, const_table=None,
              idx: int = 0, *, genome: str = "tree") -> str:
    """Render one genome row as an infix expression string (host-side).
    Both forms emit the identical grammar (`core/parse.py` round-trips
    it); `genome="postfix"` walks the instruction stream with a string
    stack instead of recursing the heap."""
    op_row = np.asarray(op_row)
    arg_row = np.asarray(arg_row)
    if genome == "postfix":
        return _postfix_to_string(op_row, arg_row, feature_names, const_table)
    o = int(op_row[idx])
    if o == prim.EMPTY:
        return "∅"
    if o in (prim.CONST, prim.FEATURE):
        return _terminal_str(o, int(arg_row[idx]), feature_names, const_table)
    p = prim.FUNCTIONS[o - 3]
    lhs = to_string(op_row, arg_row, feature_names, const_table, 2 * idx + 1)
    if p.arity == 1:
        return f"{p.name}({lhs})"
    rhs = to_string(op_row, arg_row, feature_names, const_table, 2 * idx + 2)
    sym = _INFIX_SYM.get(p.name)
    return f"({lhs} {sym} {rhs})" if sym else f"{p.name}({lhs}, {rhs})"


def _postfix_to_string(op_row, arg_row, feature_names, const_table) -> str:
    """String-stack rendering of one postfix stream — same output as the
    heap renderer on the equivalent tree, character for character."""
    stack: list[str] = []
    for t in range(op_row.shape[0]):
        o = int(op_row[t])
        if o == prim.EMPTY:
            break
        if o in (prim.CONST, prim.FEATURE):
            stack.append(_terminal_str(o, int(arg_row[t]), feature_names,
                                       const_table))
            continue
        p = prim.FUNCTIONS[o - 3]
        if p.arity == 1:
            stack.append(f"{p.name}({stack.pop()})")
        else:
            rhs = stack.pop()
            lhs = stack.pop()
            sym = _INFIX_SYM.get(p.name)
            stack.append(f"({lhs} {sym} {rhs})" if sym
                         else f"{p.name}({lhs}, {rhs})")
    if not stack:
        return "∅"
    if len(stack) != 1:
        raise ValueError(f"malformed postfix stream: {len(stack)} results")
    return stack[0]


def tree_sizes(op) -> jnp.ndarray:
    """Number of non-EMPTY nodes per tree."""
    return (op != prim.EMPTY).sum(-1)


def _check_heap_invariants(op: np.ndarray, spec: TreeSpec) -> None:
    """Assert heap well-formedness I1–I4."""
    N = spec.num_nodes
    depth = depth_table(N)
    arity = prim.ARITY[op]
    assert (op[:, 0] != prim.EMPTY).all(), "I1: empty root"
    for i in range((N - 1) // 2):
        l, r = op[:, 2 * i + 1], op[:, 2 * i + 2]
        a = arity[:, i]
        assert ((a < 1) | (l != prim.EMPTY)).all(), f"I2: missing left child of {i}"
        assert ((a < 2) | (r != prim.EMPTY)).all(), f"I2: missing right child of {i}"
        assert ((a == 2) | (r == prim.EMPTY)).all(), f"I2/I3: stray right child of {i}"
        assert ((a >= 1) | (l == prim.EMPTY)).all(), f"I3: stray left child of {i}"
    leaf = depth == spec.max_depth
    assert (prim.ARITY[op[:, leaf]] == 0).all(), "I4: function at max depth"


def _check_postfix_invariants(op: np.ndarray, spec: TreeSpec) -> None:
    """Assert postfix well-formedness P1–P5."""
    N = spec.num_nodes
    arity = prim.ARITY[op]
    active = op != prim.EMPTY
    lens = active.sum(-1)
    idx = np.arange(N)
    assert (lens >= 1).all(), "P1: empty program"
    assert (active == (idx[None, :] < lens[:, None])).all(), \
        "P1: EMPTY slot inside the active prefix"
    assert (arity[:, 0] == 0).all(), "P2: first instruction is not a terminal"
    S = np.cumsum(1 - arity, axis=-1)
    act_S = np.where(active, S, 1)
    assert (act_S >= 1).all(), "P3: operand-stack underflow mid-program"
    assert (S[np.arange(op.shape[0]), lens - 1] == 1).all(), \
        "P4: program does not leave exactly one result"
    assert (act_S <= spec.stack_size).all(), \
        f"P5: operand-stack depth exceeds stack_size={spec.stack_size}"


_FORM_CHECKS = {"tree": _check_heap_invariants,
                "postfix": _check_postfix_invariants}


def check_invariants(op: np.ndarray, spec: TreeSpec) -> None:
    """Assert well-formedness of a population in the spec's genome form
    (host-side, used by tests): heap invariants I1–I4 for genome="tree",
    postfix invariants P1–P5 for genome="postfix".

    If the rows FAIL their declared form but satisfy the other one, the
    population is almost certainly a state saved under the other encoding
    (e.g. an old pre-postfix checkpoint restored into a postfix config) —
    that raises a ValueError naming the mismatch instead of a bare
    AssertionError.
    """
    op = np.asarray(op).reshape(-1, spec.num_nodes)
    assert ((op >= 0) & (op < len(prim.ARITY))).all(), "invalid opcode"
    other = {"tree": "postfix", "postfix": "tree"}[spec.genome]
    try:
        _FORM_CHECKS[spec.genome](op, spec)
    except AssertionError as err:
        try:
            _FORM_CHECKS[other](op, spec)
        except AssertionError:
            raise err from None
        raise ValueError(
            f"population violates the {spec.genome!r} genome invariants "
            f"({err}) but satisfies the {other!r} form — was this state "
            f"loaded from a checkpoint written under TreeSpec."
            f"genome={other!r}? Convert it with trees.heap_to_postfix / "
            f"trees.postfix_to_heap (host) or re-initialize, and keep "
            f"TreeSpec.genome consistent with the stored population."
        ) from err
