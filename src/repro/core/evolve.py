"""Genetic operators on heap-tensor populations — all jittable.

Karoo GP's tournament selection, reproduction, mutation and crossover
(`fx_evolve_*`) are "computationally inexpensive bookkeeping" next to
evaluation (paper §2.3) — but on TPU they must still be branch-free so the
whole generation step stays one program. Subtree crossover/mutation become
integer path arithmetic on heap indices:

  heap slot i ↔ 1-based code (i+1) whose binary digits below the leading 1
  spell the root-to-node path. Moving the subtree rooted at source slot b
  into target slot a maps every target descendant t (relative path suffix
  s, depth k below a) to source slot ((b+1) << k) + s - 1.

Transplants that would overflow the depth ceiling are repaired by demoting
dangling max-depth function nodes to terminals — the same bloat ceiling
Karoo enforces at generation time (DESIGN.md §7.2).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import primitives as prim
from repro.core.trees import (TreeSpec, depth_table, generate_population,
                              subtree_spans, tree_sizes)


# --- random node choice ------------------------------------------------------


def _random_active_node(key, op):
    """Uniform random non-EMPTY slot per tree via Gumbel-argmax.

    op: int32[..., N] → int32[...] heap index.
    """
    g = jax.random.gumbel(key, op.shape)
    score = jnp.where(op != prim.EMPTY, g, -jnp.inf)
    return jnp.argmax(score, axis=-1).astype(jnp.int32)


# --- subtree transplant (shared by crossover + branch mutation) -------------


def _transplant(op_t, arg_t, op_s, arg_s, a, b, spec: TreeSpec):
    """Replace the subtree at slot `a` of the target tree with the subtree
    at slot `b` of the source tree. Single tree; vmap for populations."""
    N = spec.num_nodes
    DEPTH = jnp.asarray(depth_table(N))
    t = jnp.arange(N, dtype=jnp.int32)
    k = DEPTH - DEPTH[a]  # relative depth of slot t under a
    kc = jnp.maximum(k, 0)
    in_sub = (k >= 0) & (((t + 1) >> kc) == (a + 1))
    rel = (t + 1) - ((a + 1) << kc)  # path suffix as offset in level k
    src1 = ((b + 1) << kc) + rel  # 1-based source slot
    valid = in_sub & (src1 <= N)
    src = jnp.clip(src1 - 1, 0, N - 1)
    new_op = jnp.where(valid, op_s[src], jnp.where(in_sub, prim.EMPTY, op_t))
    new_arg = jnp.where(valid, arg_s[src], jnp.where(in_sub, 0, arg_t))
    # Depth-ceiling repair (I4): a function copied to the last level has no
    # room for children -> demote to a feature terminal.
    at_leaf = DEPTH == spec.max_depth
    dangling = at_leaf & (jnp.asarray(prim.ARITY)[new_op] > 0)
    new_op = jnp.where(dangling, prim.FEATURE, new_op)
    new_arg = jnp.where(dangling, (t + new_arg) % spec.n_features, new_arg)
    return new_op, new_arg


_transplant_pop = jax.vmap(_transplant, in_axes=(0, 0, 0, 0, 0, 0, None))


# --- postfix splicing (crossover + branch mutation on linear genomes) --------


def _splice_row(op_a, arg_a, op_b, arg_b, sa, ea, sb, eb, spec: TreeSpec):
    """Replace the subexpression [sa, ea] of postfix program A with the
    subexpression [sb, eb] of program B — pure arange-mask splicing, the
    payoff of the linear encoding (no heap path arithmetic, no subtree
    depth repair).

    Offspring that would exceed NODES or the operand-stack bound (P5) are
    rejected: the row returns parent A unchanged (a valid, if boring, GP
    operator outcome — mirrors Karoo retrying an oversize crossover).
    Single row; vmapped as `_splice_pop`."""
    N = spec.num_nodes
    t = jnp.arange(N, dtype=jnp.int32)
    len_a = jnp.sum(op_a != prim.EMPTY).astype(jnp.int32)
    lb = eb - sb + 1
    new_len = len_a - (ea - sa + 1) + lb
    in_pre = t < sa
    in_ins = (t >= sa) & (t < sa + lb)
    in_tail = (t >= sa + lb) & (t < new_len)
    idx_b = jnp.clip(sb + t - sa, 0, N - 1)
    idx_tail = jnp.clip(t - lb + (ea - sa + 1), 0, N - 1)
    cand_op = jnp.where(
        in_pre, op_a,
        jnp.where(in_ins, op_b[idx_b],
                  jnp.where(in_tail, op_a[idx_tail], prim.EMPTY)))
    cand_arg = jnp.where(
        in_pre, arg_a,
        jnp.where(in_ins, arg_b[idx_b],
                  jnp.where(in_tail, arg_a[idx_tail], 0)))
    # Both spans are whole subexpressions, so the splice stays stack-balanced;
    # only the length and peak-depth bounds can break.
    S = jnp.cumsum(1 - jnp.asarray(prim.ARITY)[cand_op])
    peak = jnp.max(jnp.where(t < new_len, S, 0))
    ok = (new_len <= N) & (peak <= spec.stack_size)
    return (jnp.where(ok, cand_op, op_a), jnp.where(ok, cand_arg, arg_a))


_splice_pop = jax.vmap(_splice_row, in_axes=(0, 0, 0, 0, 0, 0, 0, 0, None))


def _random_subexpr(key, op):
    """(start, end) of a uniform random subexpression per postfix row.
    Every active position ends exactly one subexpression, so a uniform
    draw over active slots matches the heap path's uniform node pick."""
    end = _random_active_node(key, op)
    start = jnp.take_along_axis(subtree_spans(op), end[..., None], axis=-1)
    return start[..., 0], end


def crossover_postfix(key, op_a, arg_a, op_b, arg_b, spec: TreeSpec):
    """Subtree crossover on linear genomes: splice a random subexpression
    of B over a random subexpression of A."""
    ka, kb = jax.random.split(key)
    sa, ea = _random_subexpr(ka, op_a)
    sb, eb = _random_subexpr(kb, op_b)
    return _splice_pop(op_a, arg_a, op_b, arg_b, sa, ea, sb, eb, spec)


def mutate_branch_postfix(key, op, arg, spec: TreeSpec):
    """Branch mutation on linear genomes: splice a fresh random program
    (its full stream: [0, len-1]) over a random subexpression."""
    P = op.shape[0]
    kp, kg = jax.random.split(key)
    sa, ea = _random_subexpr(kp, op)
    fresh_op, fresh_arg = generate_population(kg, P, spec)
    sb = jnp.zeros((P,), jnp.int32)
    eb = (tree_sizes(fresh_op) - 1).astype(jnp.int32)
    return _splice_pop(op, arg, fresh_op, fresh_arg, sa, ea, sb, eb, spec)


# --- operators ----------------------------------------------------------------


def crossover(key, op_a, arg_a, op_b, arg_b, spec: TreeSpec):
    """Subtree crossover: offspring = parent A with a random branch of B
    grafted at a random point (Karoo's fx_evolve_crossover)."""
    P = op_a.shape[0]
    del P  # shapes carried by the population arrays themselves
    ka, kb = jax.random.split(key)
    pt_a = _random_active_node(ka, op_a)
    pt_b = _random_active_node(kb, op_b)
    return _transplant_pop(op_a, arg_a, op_b, arg_b, pt_a, pt_b, spec)


def mutate_branch(key, op, arg, spec: TreeSpec):
    """Branch mutation: replace a random subtree with a fresh random tree
    (Karoo's fx_evolve_branch_mutate)."""
    P = op.shape[0]
    kp, kg = jax.random.split(key)
    pt = _random_active_node(kp, op)
    fresh_op, fresh_arg = generate_population(kg, P, spec)
    root = jnp.zeros((P,), jnp.int32)
    return _transplant_pop(op, arg, fresh_op, fresh_arg, pt, root, spec)


def mutate_point(key, op, arg, spec: TreeSpec, p: float = 0.25):
    """Point mutation: independently redraw nodes in place, arity-preserving
    (Karoo's fx_evolve_point_mutate)."""
    km, kf, ku, kt, ks = jax.random.split(key, 5)
    hit = jax.random.bernoulli(km, p, op.shape)
    arity = jnp.asarray(prim.ARITY)[op]
    bin_ops = jnp.asarray(spec.fn_set.binary_opcodes)
    new_bin = bin_ops[jax.random.randint(kf, op.shape, 0, len(bin_ops))]
    una = spec.fn_set.unary_opcodes
    new_una = (jnp.asarray(una)[jax.random.randint(ku, op.shape, 0, max(len(una), 1))]
               if len(una) else op)
    t_op, t_arg = jax.random.bernoulli(kt, spec.p_const, op.shape), None
    new_t_op = jnp.where(t_op, prim.CONST, prim.FEATURE)
    new_t_arg = jnp.where(
        t_op,
        jax.random.randint(ks, op.shape, 0, spec.n_consts),
        jax.random.randint(ks, op.shape, 0, spec.n_features),
    )
    new_op = jnp.where(arity == 2, new_bin, jnp.where(arity == 1, new_una, new_t_op))
    new_arg = jnp.where(arity == 0, new_t_arg, arg)
    new_op = jnp.where((op == prim.EMPTY) | ~hit, op, new_op)
    new_arg = jnp.where((op == prim.EMPTY) | ~hit, arg, new_arg)
    return new_op, new_arg


def tournament(key, fitness, pop: int, size: int, active=None):
    """Minimizing tournament selection → int32[pop] winner indices.

    `size` is the static candidate-draw count; `active` (optional traced
    int32 scalar ≤ size) masks the tail candidates out of the argmin, so
    one compiled program serves per-island tournament sizes (the island
    engine passes size = max over islands and active = this island's).
    With active=None the draw and the argmin are the classic fixed-size
    tournament, bit for bit."""
    idx = jax.random.randint(key, (pop, size), 0, fitness.shape[0])
    scores = fitness[idx]
    if active is not None:
        scores = jnp.where(jnp.arange(size) < active, scores, jnp.inf)
    return idx[jnp.arange(pop), jnp.argmin(scores, axis=-1)].astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class OperatorMix:
    """Karoo Table 2 defaults: 10% reproduction / 20% mutation / 70% crossover.
    Mutation is split evenly between point and branch mutation."""

    reproduce: float = 0.10
    mutate_point: float = 0.10
    mutate_branch: float = 0.10
    crossover: float = 0.70

    def __hash__(self):
        return hash((self.reproduce, self.mutate_point, self.mutate_branch, self.crossover))

    def probs(self) -> np.ndarray:
        """f32[4] probability vector in `next_generation_arrays` order."""
        return np.asarray([self.reproduce, self.mutate_point,
                           self.mutate_branch, self.crossover], np.float32)


def next_generation_arrays(key, op, arg, fitness, spec: TreeSpec, probs,
                           tourn_size: int = 10, elitism: int = 1,
                           n_out: int | None = None, tourn_active=None,
                           point_rate=None):
    """`next_generation` with the operator mix as *traced arrays* — the
    vectorized surface the island engine vmaps over the island axis so
    one compiled program runs I different search regimes.

    probs:        f32[4] operator probabilities in (reproduce,
                  mutate_point, mutate_branch, crossover) order —
                  `OperatorMix.probs()` per island.
    tourn_size:   static candidate-draw count (max over islands).
    tourn_active: optional traced int32 — this island's effective
                  tournament size (≤ tourn_size; None = tourn_size).
    point_rate:   optional traced f32 — this island's point-mutation
                  redraw probability (None = the 0.25 default).

    With probs built from an OperatorMix and the optional args left None
    this is bit-for-bit the classic static path (`next_generation` is a
    thin jitted wrapper over it). Plain traced function: call it inside
    your own jit/vmap."""
    P = n_out or op.shape[0]
    k_op, k_t1, k_t2, k_x, k_mb, k_mp = jax.random.split(key, 6)

    choice = jax.random.categorical(k_op, jnp.log(probs), shape=(P,))

    parent_a = tournament(k_t1, fitness, P, tourn_size, tourn_active)
    parent_b = tournament(k_t2, fitness, P, tourn_size, tourn_active)
    op_a, arg_a = op[parent_a], arg[parent_a]
    op_b, arg_b = op[parent_b], arg[parent_b]

    if spec.genome == "postfix":
        op_x, arg_x = crossover_postfix(k_x, op_a, arg_a, op_b, arg_b, spec)
        op_mb, arg_mb = mutate_branch_postfix(k_mb, op_a, arg_a, spec)
    else:
        op_x, arg_x = crossover(k_x, op_a, arg_a, op_b, arg_b, spec)
        op_mb, arg_mb = mutate_branch(k_mb, op_a, arg_a, spec)
    # mutate_point is arity-preserving in place — valid on both forms.
    if point_rate is None:
        op_mp, arg_mp = mutate_point(k_mp, op_a, arg_a, spec)
    else:
        op_mp, arg_mp = mutate_point(k_mp, op_a, arg_a, spec, p=point_rate)

    c = choice[:, None]
    new_op = jnp.where(c == 0, op_a, jnp.where(c == 1, op_mp, jnp.where(c == 2, op_mb, op_x)))
    new_arg = jnp.where(c == 0, arg_a, jnp.where(c == 1, arg_mp, jnp.where(c == 2, arg_mb, arg_x)))

    if elitism:
        best = jnp.argsort(fitness)[:elitism]
        new_op = new_op.at[:elitism].set(op[best])
        new_arg = new_arg.at[:elitism].set(arg[best])
    return new_op, new_arg


def make_island_breeder(spec: TreeSpec, tourn_size: int, elitism: int,
                        n_out: int | None = None, fold=None):
    """The ONE per-island breeding closure every island path vmaps over
    its island axis — single-device engine, mesh shards (which pass
    their model-rank as `fold` so each rank breeds a decorrelated slice)
    and the host backend's cached program all share it, so the
    heterogeneous-search contract cannot drift between paths.

    Returns breed(key, op_i, arg_i, fitness_i, probs_i, tourn_active_i,
    point_rate_i) -> (advanced key, new_op, new_arg); `fold` (optional
    traced int) is folded into the draw key after the split."""

    def breed(key, op_i, arg_i, fit_i, probs_i, tourn_i, pp_i):
        key, k_next = jax.random.split(key)
        if fold is not None:
            k_next = jax.random.fold_in(k_next, fold)
        new_op, new_arg = next_generation_arrays(
            k_next, op_i, arg_i, fit_i, spec, probs_i, tourn_size, elitism,
            n_out, tourn_active=tourn_i, point_rate=pp_i)
        return key, new_op, new_arg

    return breed


@partial(jax.jit, static_argnames=("spec", "mix", "tourn_size", "elitism", "n_out"))
def next_generation(key, op, arg, fitness, spec: TreeSpec, mix: OperatorMix = OperatorMix(),
                    tourn_size: int = 10, elitism: int = 1, n_out: int | None = None):
    """One full selection + variation step. [P,N] -> [n_out,N], fixed shapes.

    Every offspring slot draws an operator from the mix; all operator
    outputs are computed vectorized and the per-slot result selected —
    branch-free, so the program is identical every generation (trees are
    tiny: the <3x redundant work is noise next to evaluation, paper §2.3).
    `n_out` decouples offspring count from parent-pool size so a
    model-axis shard can produce just its slice of the next generation.

    Inside a jitted program (engine step/block) this inlines into the
    caller's trace. Host loops calling it repeatedly should go through
    `repro.gp.backends.host_next_generation(spec, mix, tourn_size,
    elitism)` instead — one cached compiled program per operator
    configuration, shared across call sites and sessions. Heterogeneous
    per-island operator parameters go through `next_generation_arrays`.
    """
    probs = jnp.array([mix.reproduce, mix.mutate_point, mix.mutate_branch, mix.crossover])
    return next_generation_arrays(key, op, arg, fitness, spec, probs,
                                  tourn_size, elitism, n_out)
