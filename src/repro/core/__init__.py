"""Core GP engine — the paper's contribution as a composable JAX module.

Tensorized tree populations, vectorized evaluation, fitness kernels,
jittable genetic operators, and the sharded generation step.
"""
from repro.core.engine import GPConfig, GPState, evolve_step, init_state, run, sharded_evolve_step  # noqa: F401
from repro.core.fitness import (  # noqa: F401
    FitnessKernel, FitnessSpec, available_kernels, get_kernel, register_kernel,
)
from repro.core.trees import TreeSpec  # noqa: F401
