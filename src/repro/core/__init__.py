"""Core GP engine — the paper's contribution as a composable JAX module.

Tensorized tree populations, vectorized evaluation, fitness kernels,
jittable genetic operators, and the sharded generation step.
"""
from repro.core.engine import (  # noqa: F401
    GPConfig, GPState, evolve_block, evolve_step, init_state, run,
    sharded_evolve_block, sharded_evolve_step,
)
from repro.core.evolve import OperatorMix  # noqa: F401
from repro.core.fitness import (  # noqa: F401
    FitnessKernel, FitnessSpec, available_kernels, get_kernel, register_kernel,
)
from repro.core.islands import IslandConfig  # noqa: F401
from repro.core.trees import TreeSpec  # noqa: F401
