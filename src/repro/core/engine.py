"""KarooEngine — the generation loop, single-device and mesh-sharded.

Workflow (paper §2.4): build population → evaluate fitness → select →
apply genetic operators → repeat. Step 2 is the parallel hot spot; here it
is one jitted program per generation (`evolve_step`), or — the device-
resident fast path — one jitted program per K-generation *evolution
block* (`evolve_block` / `sharded_evolve_block`): a `lax.scan` over the
same step body, early stop as a branch-free on-device freeze, and the
per-generation best-fitness stream returned as a [K] array so the host
synchronizes once per block instead of once per generation. Under
`shard_map` the step distributes as:

    data axis   : dataset columns sharded; per-tree weighted fitness
                  moments are `psum`-reduced then finalized (the paper's
                  vectorized-evaluation axis; two-pass protocol, so even
                  pearson/r2 statistics shard here)
    model axis  : population sharded; selection needs the global fitness
                  vector + parent pool, an O(pop·nodes) `all_gather` (tiny
                  next to evaluation, paper §2.3)
    pod axis    : island-model populations with periodic elite migration
                  (core/islands.py) — the multi-pod story

Engine state is a pytree, so checkpointing/restore reuses ckpt/ unchanged.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import evolve as ev
from repro.core import fitness as fit
from repro.core.trees import TreeSpec, generate_population


@dataclasses.dataclass(frozen=True)
class GPConfig:
    """Run-time parameters (paper Table 2 defaults)."""

    name: str = "karoo"
    pop_size: int = 100
    tree_spec: TreeSpec = TreeSpec()
    fitness: fit.FitnessSpec = fit.FitnessSpec()
    mix: ev.OperatorMix = ev.OperatorMix()
    tourn_size: int = 10
    generations: int = 30
    elitism: int = 1
    parsimony: float = 0.0  # bloat pressure: selection fitness += p * size
    stop_fitness: float | None = None  # early termination threshold (run())
    eval_impl: str = "jnp"  # any jittable name in repro.gp.backends
    data_tile: int = 1024  # pallas data-tile (lane-dim multiple of 128)
    migrate_every: int = 10  # pod-axis island migration period
    migrate_k: int = 4  # elites exchanged per migration

    def __hash__(self):
        return hash((self.name, self.pop_size, self.tree_spec, self.fitness, self.mix,
                     self.tourn_size, self.generations, self.elitism, self.parsimony,
                     self.stop_fitness, self.eval_impl,
                     self.data_tile, self.migrate_every, self.migrate_k))


class GPState(NamedTuple):
    key: jax.Array
    op: jax.Array  # int32[P, N]
    arg: jax.Array  # int32[P, N]
    fitness: jax.Array  # float32[P] (of current population, minimize)
    best_op: jax.Array  # int32[N]
    best_arg: jax.Array  # int32[N]
    best_fitness: jax.Array  # float32[]
    generation: jax.Array  # int32[]


def _eval_fitness(cfg: GPConfig, op, arg, X, y, weight, const_table):
    """Dispatch to the EvalBackend registered under `cfg.eval_impl`
    (repro.gp.backends — pallas fused kernel, jnp tiled reference, or any
    user-registered jittable backend). `weight` is the dataset-padding
    mask (f32[D], 0.0 on padded points) or None for unpadded data."""
    from repro.gp.backends import get_backend

    backend = get_backend(cfg.eval_impl)
    if not backend.jittable:
        raise ValueError(
            f"eval backend {backend.name!r} is host-only and cannot run inside "
            f"the jitted generation step; drive it through repro.gp.GPSession")
    return backend.fitness(op, arg, X, y, const_table, cfg.tree_spec, cfg.fitness,
                           weight=weight, data_tile=cfg.data_tile)


def _eval_moments(cfg: GPConfig, op, arg, X, y, weight, const_table):
    """Phase 1 of the two-pass fitness protocol on the backend registered
    under `cfg.eval_impl`: f32[P, M] weighted moment partials for THIS
    shard's data. The mesh step `psum`s them across the data axis and
    finalizes with `FitnessKernel.reduce_moments` — how non-decomposable
    objectives (pearson, r2) run on any `MeshTopology`."""
    from repro.gp.backends import get_backend

    backend = get_backend(cfg.eval_impl)
    if backend.moments is None:
        raise ValueError(
            f"eval backend {backend.name!r} exposes no moment pass and cannot "
            f"evaluate fitness under a data-sharded mesh")
    return backend.moments(op, arg, X, y, const_table, cfg.tree_spec, cfg.fitness,
                           weight=weight, data_tile=cfg.data_tile)


def init_state(cfg: GPConfig, key, seeds=None, feature_names=None) -> GPState:
    """Fresh state; `seeds` (expression strings) populate the first slots —
    Karoo's customized seed populations (paper §2.2)."""
    k0, k1 = jax.random.split(key)
    if seeds:
        from repro.core.parse import seed_population

        op, arg = seed_population(seeds, cfg.tree_spec, cfg.pop_size, k1,
                                  feature_names)
    else:
        op, arg = generate_population(k1, cfg.pop_size, cfg.tree_spec)
    N = cfg.tree_spec.num_nodes
    return GPState(
        key=k0, op=op, arg=arg,
        fitness=jnp.full((cfg.pop_size,), jnp.inf, jnp.float32),
        best_op=jnp.zeros((N,), jnp.int32), best_arg=jnp.zeros((N,), jnp.int32),
        best_fitness=jnp.asarray(jnp.inf, jnp.float32),
        generation=jnp.asarray(0, jnp.int32),
    )


def _step_body(cfg: GPConfig, state: GPState, X, y, weight) -> GPState:
    """One generation's computation — shared verbatim by the per-step jit
    (`evolve_step`) and the scanned block (`evolve_block`), so K scanned
    steps are bitwise-identical to K dispatched steps."""
    const_table = cfg.tree_spec.const_table()
    fitness = _eval_fitness(cfg, state.op, state.arg, X, y, weight, const_table)
    # best tracked on RAW fitness; selection may add parsimony pressure
    i = jnp.argmin(fitness)
    improved = fitness[i] < state.best_fitness
    best_op = jnp.where(improved, state.op[i], state.best_op)
    best_arg = jnp.where(improved, state.arg[i], state.best_arg)
    best_fit = jnp.minimum(fitness[i], state.best_fitness)

    sel_fitness = fitness
    if cfg.parsimony:
        from repro.core.trees import tree_sizes

        sel_fitness = fitness + cfg.parsimony * tree_sizes(state.op).astype(jnp.float32)

    key, k_next = jax.random.split(state.key)
    new_op, new_arg = ev.next_generation(
        k_next, state.op, state.arg, sel_fitness, cfg.tree_spec, cfg.mix,
        cfg.tourn_size, cfg.elitism)
    return GPState(key, new_op, new_arg, fitness, best_op, best_arg, best_fit,
                   state.generation + 1)


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(1,))
def evolve_step(cfg: GPConfig, state: GPState, X, y, weight=None) -> GPState:
    """One generation on a single device. X: [F, D] feature-major, y: [D];
    `weight` (f32[D] or None) masks dataset-padding points out of fitness."""
    return _step_body(cfg, state, X, y, weight)


def _block_done(cfg: GPConfig, state: GPState, i, limit):
    """Branch-free freeze predicate for step `i` of a block: True once
    `best_fitness` has reached `cfg.stop_fitness` (on-device early stop)
    or `i` has reached the dynamic `limit` (a traced step budget that
    lets ONE compiled fixed-length block program serve ragged block
    boundaries — checkpoint/callback phases, final partial blocks —
    without recompiling per distinct length)."""
    done = jnp.asarray(False)
    if cfg.stop_fitness is not None:
        done = state.best_fitness <= cfg.stop_fitness
    if limit is not None:
        done = done | (i >= limit)
    return done


def _freeze(done, prev: GPState, new: GPState) -> GPState:
    """Carry `prev` through unchanged (PRNG key and generation counter
    included) when `done` — frozen steps are no-ops, so the host reads
    how many generations actually ran off `state.generation`."""
    return jax.tree.map(lambda p, n: jnp.where(done, p, n), prev, new)


@partial(jax.jit, static_argnames=("cfg", "n_steps"), donate_argnums=(1,))
def evolve_block(cfg: GPConfig, state: GPState, X, y, weight=None, limit=None, *,
                 n_steps: int = 1):
    """Run up to `n_steps` generations in ONE device dispatch via `lax.scan`.

    Returns (state, history) where history is the f32[n_steps] per-
    generation `best_fitness` stream — the block's metrics ride back with
    the state instead of forcing a host sync per generation. Steps freeze
    into no-ops once `cfg.stop_fitness` is reached or the step index hits
    `limit` (dynamic int32; None = run all `n_steps`), so one compiled
    program covers every block length ≤ n_steps. The freeze is a
    branch-free select, not a skip: frozen steps still execute the
    generation's compute and discard it — callers bound the waste by
    choosing n_steps (GPSession caps it at the configured period, or
    _STOP_CHECK_SPAN when only stop_fitness is armed)."""

    def body(s, i):
        nxt = _step_body(cfg, s, X, y, weight)
        done = _block_done(cfg, s, i, limit)
        if cfg.stop_fitness is not None or limit is not None:
            nxt = _freeze(done, s, nxt)
        return nxt, nxt.best_fitness

    state, history = jax.lax.scan(body, state, jnp.arange(n_steps))
    return state, history


def run(cfg: GPConfig, X, y, key=None, generations: int | None = None,
        callback=None, seeds=None, feature_names=None) -> GPState:
    """DEPRECATED — thin forwarder to :class:`repro.gp.GPSession`, kept so
    pre-session callers don't break. X is feature-major [F, D] (the old
    contract); the session's own `fit` takes row-major data."""
    warnings.warn(
        "repro.core.run is deprecated; use repro.gp.GPSession "
        "(session = GPSession(cfg); session.fit(X_rows, y)) instead",
        DeprecationWarning, stacklevel=2)
    from repro.gp import GPSession

    sess = GPSession(cfg, feature_names=feature_names, callback=callback)
    sess.ingest(X, y, layout="features")
    sess.init(key=key, seeds=seeds)
    sess.evolve(generations)
    return sess.state


# --- mesh-sharded step --------------------------------------------------------


def _sharded_step_builder(cfg: GPConfig, mesh, *, data_axis="data",
                          model_axis="model", pod_axis: str | None = None):
    """Per-shard generation-step body + its PartitionSpecs — the common
    core of `sharded_evolve_step` (one step per dispatch) and
    `sharded_evolve_block` (K steps per dispatch via an in-shard_map
    scan). Returns (step, state_specs, data_spec, y_spec, w_spec)."""
    from repro.core.islands import migrate

    kern = fit.get_kernel(cfg.fitness.kernel)
    if kern.moments is None:
        raise ValueError(
            f"fitness kernel {kern.name!r} defines no moment pass "
            f"(moments/reduce_moments), so nothing can be psum-reduced across "
            f"the {data_axis!r} axis; register it through the two-pass protocol "
            f"(see docs/fitness-kernels.md) or run single-device")

    pod_dims = (pod_axis,) if pod_axis else ()
    n_shards = mesh.shape[model_axis]
    for a in pod_dims:
        n_shards *= mesh.shape[a]
    if cfg.pop_size % n_shards:
        raise ValueError(f"pop_size {cfg.pop_size} % population shards {n_shards} != 0")
    n_model = mesh.shape[model_axis]

    pop_spec = P((*pod_dims, model_axis))
    data_spec = P(None, data_axis)  # X is [F, D]
    y_spec = P(data_axis)
    w_spec = P(data_axis)  # padding mask rides the same axis as y
    state_specs = GPState(
        key=P(), op=pop_spec, arg=pop_spec, fitness=pop_spec,
        best_op=P(), best_arg=P(), best_fitness=P(), generation=P(),
    )

    def step(state: GPState, X, y, weight) -> GPState:
        const_table = cfg.tree_spec.const_table()
        # --- evaluate, two passes: local pop shard x local data shard
        # emits weighted moments; psum over data completes phase 1, and
        # reduce_moments finalizes — for decomposable kernels M == 1 and
        # this degenerates to the classic psum-of-partials
        partial_m = _eval_moments(cfg, state.op, state.arg, X, y, weight,
                                  const_table)
        fitness_local = kern.reduce_moments(
            jax.lax.psum(partial_m, data_axis), cfg.fitness)
        # --- selection pool = this pod's population: tiny all_gather
        fitness_g = jax.lax.all_gather(fitness_local, model_axis, tiled=True)
        op_g = jax.lax.all_gather(state.op, model_axis, tiled=True)
        arg_g = jax.lax.all_gather(state.arg, model_axis, tiled=True)

        # --- pod-local best, then global best across pods (replicated)
        i = jnp.argmin(fitness_g)
        cand_fit, cand_op, cand_arg = fitness_g[i], op_g[i], arg_g[i]
        if pod_axis:
            pods_fit = jax.lax.all_gather(cand_fit, pod_axis)  # [n_pods]
            pods_op = jax.lax.all_gather(cand_op, pod_axis)  # [n_pods, N]
            pods_arg = jax.lax.all_gather(cand_arg, pod_axis)
            j = jnp.argmin(pods_fit)
            cand_fit, cand_op, cand_arg = pods_fit[j], pods_op[j], pods_arg[j]
        improved = cand_fit < state.best_fitness
        best_op = jnp.where(improved, cand_op, state.best_op)
        best_arg = jnp.where(improved, cand_arg, state.best_arg)
        best_fit = jnp.minimum(cand_fit, state.best_fitness)

        # --- offspring for this shard's slice only (decorrelated RNG)
        rank = jax.lax.axis_index(model_axis)
        key = state.key
        if pod_axis:
            key = jax.random.fold_in(key, jax.lax.axis_index(pod_axis))
        key = jax.random.fold_in(key, state.generation)
        k_rank = jax.random.fold_in(key, rank)
        n_local = cfg.pop_size // n_shards
        new_op, new_arg = ev.next_generation(
            k_rank, op_g, arg_g, fitness_g, cfg.tree_spec, cfg.mix,
            cfg.tourn_size, elitism=0, n_out=n_local)
        # elitism: rank 0 of each pod re-seeds the pod's own champion
        if cfg.elitism:
            keep = rank == 0
            new_op = new_op.at[0].set(jnp.where(keep, op_g[i], new_op[0]))
            new_arg = new_arg.at[0].set(jnp.where(keep, arg_g[i], new_arg[0]))
        if pod_axis:
            order = jnp.argsort(fitness_g)[:cfg.migrate_k]
            new_op, new_arg = migrate(
                cfg, new_op, new_arg, op_g[order], arg_g[order],
                state.generation, pod_axis, is_receiver=rank == n_model - 1)
        return GPState(state.key, new_op, new_arg, fitness_local, best_op, best_arg,
                       best_fit, state.generation + 1)

    return step, state_specs, data_spec, y_spec, w_spec


def sharded_evolve_step(cfg: GPConfig, mesh, *, data_axis="data", model_axis="model",
                        pod_axis: str | None = None):
    """Build a shard_map'd generation step for `mesh`.

    Shardings: X, y, weight on (data,); the population's leading axis on
    (pod, model) — the pod slices are the islands, the model slices are
    a pod's parallel evaluation shards. Returns (step_fn, specs dict)
    ready for jit/lower; step_fn(state, X, y, weight) — weight is the
    f32[D] dataset-padding mask (all-ones when nothing was padded).
    best_* is replicated (global argmin over pods).
    """
    step, state_specs, data_spec, y_spec, w_spec = _sharded_step_builder(
        cfg, mesh, data_axis=data_axis, model_axis=model_axis, pod_axis=pod_axis)
    smapped = compat.shard_map(
        step, mesh=mesh,
        in_specs=(state_specs, data_spec, y_spec, w_spec),
        out_specs=state_specs,
    )
    return smapped, dict(state=state_specs, X=data_spec, y=y_spec, weight=w_spec)


def sharded_evolve_block(cfg: GPConfig, mesh, *, n_steps: int, data_axis="data",
                         model_axis="model", pod_axis: str | None = None):
    """Build a shard_map'd K-generation evolution block for `mesh`.

    The `lax.scan` lives INSIDE shard_map, so one dispatch runs `n_steps`
    generations — collectives included — with no host round-trip between
    them. Early stop follows the same branch-free freeze as the
    single-device block (`best_fitness` is replicated, so every shard
    takes the same freeze decision). Returns (block_fn, specs dict);
    block_fn(state, X, y, weight, limit) -> (state, history f32[n_steps])
    — `limit` is the replicated dynamic step budget (pass n_steps to run
    the full block), history replicated (it streams the replicated
    best_fitness).
    """
    step, state_specs, data_spec, y_spec, w_spec = _sharded_step_builder(
        cfg, mesh, data_axis=data_axis, model_axis=model_axis, pod_axis=pod_axis)

    def block(state: GPState, X, y, weight, limit):
        def body(s, i):
            nxt = _freeze(_block_done(cfg, s, i, limit), s, step(s, X, y, weight))
            return nxt, nxt.best_fitness

        return jax.lax.scan(body, state, jnp.arange(n_steps))

    smapped = compat.shard_map(
        block, mesh=mesh,
        in_specs=(state_specs, data_spec, y_spec, w_spec, P()),
        out_specs=(state_specs, P()),
    )
    return smapped, dict(state=state_specs, X=data_spec, y=y_spec, weight=w_spec,
                         limit=P(), history=P())
