"""KarooEngine — the generation loop, single-device and mesh-sharded.

Workflow (paper §2.4): build population → evaluate fitness → select →
apply genetic operators → repeat. Step 2 is the parallel hot spot; here it
is one jitted program per generation (`evolve_step`), or — the device-
resident fast path — one jitted program per K-generation *evolution
block* (`evolve_block` / `sharded_evolve_block`): a `lax.scan` over the
same step body, early stop as a branch-free on-device freeze, and the
per-generation best-fitness stream returned as a [K] array so the host
synchronizes once per block instead of once per generation. Under
`shard_map` the step distributes as:

    data axis   : dataset columns sharded; per-tree weighted fitness
                  moments are `psum`-reduced then finalized (the paper's
                  vectorized-evaluation axis; two-pass protocol, so even
                  pearson/r2 statistics shard here)
    model axis  : population sharded; selection needs the global fitness
                  vector + parent pool, an O(pop·nodes) `all_gather` (tiny
                  next to evaluation, paper §2.3)
    pod axis    : island-model populations with periodic elite migration
                  (core/islands.py) — the multi-pod story

Engine state is a pytree, so checkpointing/restore reuses ckpt/ unchanged.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import evolve as ev
from repro.core import fitness as fit
from repro.core.islands import IslandConfig
from repro.core.trees import TreeSpec, generate_population


@dataclasses.dataclass(frozen=True)
class GPConfig:
    """Run-time parameters (paper Table 2 defaults).

    `island` is the first-class population layout: `islands > 1` makes
    every run `I` islands of `pop_size` trees (`op: int32[I, P, N]`) on
    ANY topology — vmapped on one device, sharded over the mesh pod axis,
    or both (see core/islands.py). `migrate_every`/`migrate_k` are legacy
    flat aliases kept for the pre-island surface: setting them away from
    their defaults folds them into `island`, and after construction they
    always mirror `island.migrate_every`/`island.migrate_k`."""

    name: str = "karoo"
    pop_size: int = 100  # trees per island (total when islands == 1)
    tree_spec: TreeSpec = TreeSpec()
    fitness: fit.FitnessSpec = fit.FitnessSpec()
    mix: ev.OperatorMix = ev.OperatorMix()
    tourn_size: int = 10
    generations: int = 30
    elitism: int = 1
    parsimony: float = 0.0  # bloat pressure: selection fitness += p * size
    stop_fitness: float | None = None  # early termination threshold (run())
    eval_impl: str = "jnp"  # any jittable name in repro.gp.backends
    data_tile: int = 1024  # pallas data-tile (lane-dim multiple of 128)
    elite_cache: bool = True  # skip re-evaluating unchanged elites
    # population-wide subexpression dedup (postfix genomes; docs/genomes.md):
    #   "off"      plain per-tree evaluation
    #   "exact"    evaluate each distinct subtree once per generation —
    #              BITWISE identical to "off" by construction (default)
    #   "semantic" exact tier + the elite fitness cache also keys on
    #              probe-batch output fingerprints (tolerance-pinned, may
    #              serve a cached fitness for a syntactically different
    #              but probe-equal elite)
    dedup: str = "exact"
    dedup_cap: int = 0  # unique-table rows; 0 = auto (max(64, pop rows))
    island: IslandConfig = IslandConfig()  # population layout + migration
    migrate_every: int = 10  # legacy alias for island.migrate_every
    migrate_k: int = 4  # legacy alias for island.migrate_k

    def __post_init__(self):
        if self.dedup not in ("off", "exact", "semantic"):
            raise ValueError(f"dedup must be 'off', 'exact' or 'semantic', "
                             f"got {self.dedup!r}")
        # fold a non-default flat alias into `island` ONLY where the
        # island itself still holds the default — an explicit
        # IslandConfig value always wins, so replacing the island on a
        # config that once used the alias can't resurrect the old value
        isl = self.island
        if self.migrate_every != 10 and isl.migrate_every == 10:
            isl = dataclasses.replace(isl, migrate_every=self.migrate_every)
        if self.migrate_k != 4 and isl.migrate_k == 4:
            isl = dataclasses.replace(isl, migrate_k=self.migrate_k)
        object.__setattr__(self, "island", isl)
        object.__setattr__(self, "migrate_every", isl.migrate_every)
        object.__setattr__(self, "migrate_k", isl.migrate_k)

    def __hash__(self):
        return hash((self.name, self.pop_size, self.tree_spec, self.fitness, self.mix,
                     self.tourn_size, self.generations, self.elitism, self.parsimony,
                     self.stop_fitness, self.eval_impl,
                     self.data_tile, self.elite_cache, self.dedup,
                     self.dedup_cap, self.island))


def cache_width(cfg: GPConfig) -> int:
    """E: rows of the cross-generation elite fitness cache carried in
    GPState. Elitism copies the E = cfg.elitism best rows into slots
    [:E] of the next population verbatim, so their fitness is already
    known — the step bodies skip re-evaluating them when the cached
    genomes match exactly (bitwise-identical by construction: the cached
    value IS last generation's evaluation of the same rows, and every
    eval path is row-independent). 0 disables (elite_cache off, no
    elitism, or degenerate all-elite populations)."""
    if cfg.elite_cache and 0 < cfg.elitism < cfg.pop_size:
        return cfg.elitism
    return 0


class GPState(NamedTuple):
    """Engine state pytree. With the classic single-population layout
    (islands == 1) the shapes are the un-batched legacy ones; with
    `GPConfig.island.islands == I > 1` every population leaf grows a
    leading island axis (`generation` stays a shared scalar — islands
    advance in lockstep):

                      islands == 1      islands == I
        key           uint32[2]         uint32[I, 2]   (fold_in(i) at init)
        op/arg        int32[P, N]       int32[I, P, N]
        fitness       f32[P]            f32[I, P]
        best_op/arg   int32[N]          int32[I, N]    (per-island champion)
        best_fitness  f32[]             f32[I]
        generation    int32[]           int32[]
        cache_op/arg  int32[E, N]       int32[I, E, N]  (elite fitness cache)
        cache_fit     f32[E]            f32[I, E]

    The cache rows (E = `cache_width(cfg)`; 0 disables) are last
    generation's parsimony-best genomes with their RAW fitness: elitism
    places the same rows at [:E] of the next population, so the step
    bodies compare genomes exactly and skip the elite re-evaluation on a
    match. A zero-initialized cache never matches a well-formed genome
    (slot 0 is never EMPTY in either form), so the first generation
    always evaluates fully."""

    key: jax.Array
    op: jax.Array  # int32[P, N]
    arg: jax.Array  # int32[P, N]
    fitness: jax.Array  # float32[P] (of current population, minimize)
    best_op: jax.Array  # int32[N]
    best_arg: jax.Array  # int32[N]
    best_fitness: jax.Array  # float32[]
    generation: jax.Array  # int32[]
    cache_op: jax.Array  # int32[E, N]
    cache_arg: jax.Array  # int32[E, N]
    cache_fit: jax.Array  # float32[E]


def _dedup_kwargs(cfg: GPConfig, fn) -> dict:
    """The dedup kwargs to forward to a backend callable — {} when dedup
    is off, or when the callable predates the dedup contract (a
    user-registered backend without the kwargs keeps working; it simply
    never dedups)."""
    import inspect

    if cfg.dedup == "off":
        return {}
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return {}
    if "dedup" in params or any(p.kind == p.VAR_KEYWORD
                                for p in params.values()):
        return {"dedup": cfg.dedup, "dedup_cap": cfg.dedup_cap}
    return {}


def _eval_fitness(cfg: GPConfig, op, arg, X, y, weight, const_table):
    """Dispatch to the EvalBackend registered under `cfg.eval_impl`
    (repro.gp.backends — pallas fused kernel, jnp tiled reference, or any
    user-registered jittable backend). `weight` is the dataset-padding
    mask (f32[D], 0.0 on padded points) or None for unpadded data.
    `cfg.dedup`/`cfg.dedup_cap` ride along to backends that take them —
    the exact-tier subexpression dedup is a backend-internal, bitwise
    detail of how the population gets evaluated."""
    from repro.gp.backends import get_backend

    backend = get_backend(cfg.eval_impl)
    if not backend.jittable:
        raise ValueError(
            f"eval backend {backend.name!r} is host-only and cannot run inside "
            f"the jitted generation step; drive it through repro.gp.GPSession")
    return backend.fitness(op, arg, X, y, const_table, cfg.tree_spec, cfg.fitness,
                           weight=weight, data_tile=cfg.data_tile,
                           **_dedup_kwargs(cfg, backend.fitness))


def _eval_moments(cfg: GPConfig, op, arg, X, y, weight, const_table):
    """Phase 1 of the two-pass fitness protocol on the backend registered
    under `cfg.eval_impl`: f32[P, M] weighted moment partials for THIS
    shard's data. The mesh step `psum`s them across the data axis and
    finalizes with `FitnessKernel.reduce_moments` — how non-decomposable
    objectives (pearson, r2) run on any `MeshTopology`. Dedup engages
    per shard (each shard dedups its own population slice), bitwise like
    the single-device path."""
    from repro.gp.backends import get_backend

    backend = get_backend(cfg.eval_impl)
    if backend.moments is None:
        raise ValueError(
            f"eval backend {backend.name!r} exposes no moment pass and cannot "
            f"evaluate fitness under a data-sharded mesh")
    return backend.moments(op, arg, X, y, const_table, cfg.tree_spec, cfg.fitness,
                           weight=weight, data_tile=cfg.data_tile,
                           **_dedup_kwargs(cfg, backend.moments))


def init_state(cfg: GPConfig, key, seeds=None, feature_names=None) -> GPState:
    """Fresh state; `seeds` (expression strings) populate the first slots —
    Karoo's customized seed populations (paper §2.2). With
    `cfg.island.islands > 1` the state is island-batched (see GPState):
    every island draws its own decorrelated population and PRNG key via
    `fold_in(island_idx)`; seeds populate the first slots of EVERY island
    (the random filler still differs per island)."""
    k0, k1 = jax.random.split(key)
    I = cfg.island.islands

    def one_island(kk):
        if seeds:
            from repro.core.parse import seed_population

            return seed_population(seeds, cfg.tree_spec, cfg.pop_size, kk,
                                   feature_names)
        return generate_population(kk, cfg.pop_size, cfg.tree_spec)

    N = cfg.tree_spec.num_nodes
    E = cache_width(cfg)
    if I == 1:
        op, arg = one_island(k1)
        return GPState(
            key=k0, op=op, arg=arg,
            fitness=jnp.full((cfg.pop_size,), jnp.inf, jnp.float32),
            best_op=jnp.zeros((N,), jnp.int32), best_arg=jnp.zeros((N,), jnp.int32),
            best_fitness=jnp.asarray(jnp.inf, jnp.float32),
            generation=jnp.asarray(0, jnp.int32),
            cache_op=jnp.zeros((E, N), jnp.int32),
            cache_arg=jnp.zeros((E, N), jnp.int32),
            cache_fit=jnp.full((E,), jnp.inf, jnp.float32),
        )
    if cfg.island.migrate_k > cfg.pop_size:
        raise ValueError(f"migrate_k {cfg.island.migrate_k} exceeds the "
                         f"per-island pop_size {cfg.pop_size}")
    pairs = [one_island(jax.random.fold_in(k1, i)) for i in range(I)]
    keys = jnp.stack([jax.random.fold_in(k0, i) for i in range(I)])
    return GPState(
        key=keys,
        op=jnp.stack([p[0] for p in pairs]),
        arg=jnp.stack([p[1] for p in pairs]),
        fitness=jnp.full((I, cfg.pop_size), jnp.inf, jnp.float32),
        best_op=jnp.zeros((I, N), jnp.int32),
        best_arg=jnp.zeros((I, N), jnp.int32),
        best_fitness=jnp.full((I,), jnp.inf, jnp.float32),
        generation=jnp.asarray(0, jnp.int32),
        cache_op=jnp.zeros((I, E, N), jnp.int32),
        cache_arg=jnp.zeros((I, E, N), jnp.int32),
        cache_fit=jnp.full((I, E), jnp.inf, jnp.float32),
    )


def _semantic_hit(state_slice, cache_slice, cache_fit, probe):
    """Tier-2 (semantic) cache predicate: the candidate head rows produce
    BITWISE the same outputs as the cached rows on the probe batch
    (`probe(op, arg) -> f32[..., rows, Dp]`). Guarded on an all-finite
    cached fitness so the zero-initialized cache — whose all-EMPTY rows
    probe to 0.0, as would a legitimate x-minus-x elite — can never serve
    its +inf sentinel. Collision bound: a false hit needs the two
    genomes to agree on every one of the Dp probe points yet differ
    somewhere on the full dataset (see docs/genomes.md); the parity
    contract for dedup="semantic" is therefore tolerance-pinned, not
    bitwise."""
    (s_op, s_arg) = state_slice
    (c_op, c_arg) = cache_slice
    return (jnp.all(probe(s_op, s_arg) == probe(c_op, c_arg))
            & jnp.all(jnp.isfinite(cache_fit)))


def _cached_fitness(state: GPState, eval_rows, probe=None):
    """Evaluate `state`'s population, serving rows [:E] from the elite
    fitness cache when the cached genomes match exactly.

    `eval_rows(op, arg) -> f32[rows]` evaluates any row slice. E comes
    from the state's own cache shape, so the step body needs no extra
    static plumbing. Every eval path is row-independent, so splitting
    the population at E (and skipping the head on a hit — the cached
    value IS last generation's evaluation of the identical rows) is
    bitwise-identical to one full evaluation.

    `probe` (dedup="semantic" only) widens the hit predicate: a head
    whose PROBE outputs match the cache's also serves the cached fitness
    — recurring-but-rewritten elites hit across generations, at the cost
    of the documented probe-collision bound (`_semantic_hit`)."""
    E = state.cache_op.shape[0]
    if not E:
        return eval_rows(state.op, state.arg)
    hit = (jnp.all(state.op[:E] == state.cache_op)
           & jnp.all(state.arg[:E] == state.cache_arg))
    if probe is not None:
        hit = hit | _semantic_hit(
            (state.op[:E], state.arg[:E]),
            (state.cache_op, state.cache_arg), state.cache_fit, probe)
    tail = eval_rows(state.op[E:], state.arg[E:])
    head = jax.lax.cond(
        hit, lambda: state.cache_fit,
        lambda: eval_rows(state.op[:E], state.arg[:E]))
    return jnp.concatenate([head, tail])


def _new_cache(state: GPState, fitness, sel_fitness, E: int):
    """(cache_op, cache_arg, cache_fit) for the NEXT generation: the rows
    elitism will copy to [:E] — argsort on the selection fitness, exactly
    `next_generation`'s elite pick — paired with their RAW fitness. Rows
    are taken from the EVALUATED population (`state.op`), never from the
    bred output, so a migrant landing in [:E] can only MISS (re-evaluate),
    never match a stale fitness. Works per-island on [..., P] inputs."""
    best = jnp.argsort(sel_fitness, axis=-1)[..., :E]
    cache_op = jnp.take_along_axis(state.op, best[..., None], axis=-2)
    cache_arg = jnp.take_along_axis(state.arg, best[..., None], axis=-2)
    cache_fit = jnp.take_along_axis(fitness, best, axis=-1)
    return cache_op, cache_arg, cache_fit


_PROBE_COLS = 32  # semantic-tier fingerprint batch (first Dp data columns)


def _probe_fn(cfg: GPConfig, X, const_table):
    """Semantic-tier fingerprint closure, or None unless
    cfg.dedup == "semantic": evaluate rows on the first
    min(D, _PROBE_COLS) data columns — a fixed slice of the live
    dataset, so no extra state leaf rides GPState/checkpoints. Island
    inputs ([I, R, N]) flatten into one evaluator call."""
    if cfg.dedup != "semantic":
        return None
    from repro.core.eval import evaluate_population

    Dp = min(X.shape[1], _PROBE_COLS)
    Xp = jax.lax.slice_in_dim(X, 0, Dp, axis=1)

    def probe(o, a):
        N = o.shape[-1]
        flat = evaluate_population(o.reshape(-1, N), a.reshape(-1, N), Xp,
                                   const_table, cfg.tree_spec)
        return flat.reshape(*o.shape[:-1], Dp)

    return probe


def _step_body(cfg: GPConfig, state: GPState, X, y, weight) -> GPState:
    """One generation's computation — shared verbatim by the per-step jit
    (`evolve_step`) and the scanned block (`evolve_block`), so K scanned
    steps are bitwise-identical to K dispatched steps."""
    const_table = cfg.tree_spec.const_table()
    fitness = _cached_fitness(
        state, lambda o, a: _eval_fitness(cfg, o, a, X, y, weight, const_table),
        probe=_probe_fn(cfg, X, const_table))
    # best tracked on RAW fitness; selection may add parsimony pressure
    i = jnp.argmin(fitness)
    improved = fitness[i] < state.best_fitness
    best_op = jnp.where(improved, state.op[i], state.best_op)
    best_arg = jnp.where(improved, state.arg[i], state.best_arg)
    best_fit = jnp.minimum(fitness[i], state.best_fitness)

    sel_fitness = fitness
    if cfg.parsimony:
        from repro.core.trees import tree_sizes

        sel_fitness = fitness + cfg.parsimony * tree_sizes(state.op).astype(jnp.float32)

    E = state.cache_op.shape[0]
    cache_op, cache_arg, cache_fit = (
        _new_cache(state, fitness, sel_fitness, E) if E
        else (state.cache_op, state.cache_arg, state.cache_fit))

    key, k_next = jax.random.split(state.key)
    new_op, new_arg = ev.next_generation(
        k_next, state.op, state.arg, sel_fitness, cfg.tree_spec, cfg.mix,
        cfg.tourn_size, cfg.elitism)
    return GPState(key, new_op, new_arg, fitness, best_op, best_arg, best_fit,
                   state.generation + 1, cache_op, cache_arg, cache_fit)


def _island_tables(cfg: GPConfig):
    """(probs f32[I, 4], tourn_max int, tourn int32[I], p_point f32[I]) —
    the heterogeneous-search parameter arrays one compiled program vmaps
    over (host numpy; they become constants in the jitted step)."""
    icfg = cfg.island
    tourn_max, tourn = icfg.tourn_table(cfg.tourn_size)
    return (icfg.prob_table(cfg.mix), tourn_max, tourn,
            icfg.point_rate_table())


def _island_step_body(cfg: GPConfig, state: GPState, X, y, weight) -> GPState:
    """One generation of the island-batched layout on a single device:
    evaluation runs over the flattened [I·P, N] population (one backend
    call — no vmap over the eval kernel), selection + breeding are
    vmapped over the island axis with per-island operator parameters,
    and migration routes elites across the island axis
    (islands.migrate_local). Shared verbatim by `evolve_step` and the
    scanned `evolve_block`, like the classic body."""
    from repro.core import islands as isl

    icfg = cfg.island
    I, P, N = state.op.shape
    const_table = cfg.tree_spec.const_table()

    def eval_rows(o, a):  # [I, R, N] -> [I, R], flattened into ONE backend call
        R = o.shape[1]
        return _eval_fitness(cfg, o.reshape(I * R, N), a.reshape(I * R, N),
                             X, y, weight, const_table).reshape(I, R)

    E = state.cache_op.shape[1]
    if E:
        # one hit predicate for ALL islands: a per-island cond would lower
        # to a select that evaluates both branches anyway. From gen 2 every
        # island hits every generation (migration only writes the last
        # migrate_k slots), so the all-or-nothing gate costs nothing.
        hit = (jnp.all(state.op[:, :E] == state.cache_op)
               & jnp.all(state.arg[:, :E] == state.cache_arg))
        probe = _probe_fn(cfg, X, const_table)
        if probe is not None:
            hit = hit | _semantic_hit(
                (state.op[:, :E], state.arg[:, :E]),
                (state.cache_op, state.cache_arg), state.cache_fit, probe)
        tail = eval_rows(state.op[:, E:], state.arg[:, E:])
        head = jax.lax.cond(
            hit, lambda: state.cache_fit,
            lambda: eval_rows(state.op[:, :E], state.arg[:, :E]))
        fitness = jnp.concatenate([head, tail], axis=1)
    else:
        fitness = eval_rows(state.op, state.arg)

    # per-island champion tracking on RAW fitness
    i_best = jnp.argmin(fitness, axis=1)  # [I]
    rows = jnp.arange(I)
    cand_fit = fitness[rows, i_best]
    cand_op = state.op[rows, i_best]  # [I, N]
    cand_arg = state.arg[rows, i_best]
    improved = cand_fit < state.best_fitness
    best_op = jnp.where(improved[:, None], cand_op, state.best_op)
    best_arg = jnp.where(improved[:, None], cand_arg, state.best_arg)
    best_fit = jnp.minimum(cand_fit, state.best_fitness)

    sel_fitness = fitness
    if cfg.parsimony:
        from repro.core.trees import tree_sizes

        sizes = tree_sizes(state.op.reshape(I * P, N)).reshape(I, P)
        sel_fitness = fitness + cfg.parsimony * sizes.astype(jnp.float32)

    cache_op, cache_arg, cache_fit = (
        _new_cache(state, fitness, sel_fitness, E) if E
        else (state.cache_op, state.cache_arg, state.cache_fit))

    probs, tourn_max, tourn, p_point = _island_tables(cfg)
    breed = ev.make_island_breeder(cfg.tree_spec, tourn_max, cfg.elitism)
    keys, new_op, new_arg = jax.vmap(breed)(
        state.key, state.op, state.arg, sel_fitness, jnp.asarray(probs),
        jnp.asarray(tourn), jnp.asarray(p_point))

    if icfg.migrate_k and I > 1:
        e_op, e_arg = isl.island_elites(state.op, state.arg, fitness,
                                        icfg.migrate_k)
        new_op, new_arg = isl.migrate_local(icfg, new_op, new_arg, e_op, e_arg,
                                            state.generation, cand_fit)
    return GPState(keys, new_op, new_arg, fitness, best_op, best_arg, best_fit,
                   state.generation + 1, cache_op, cache_arg, cache_fit)


def _step_body_any(cfg: GPConfig, state: GPState, X, y, weight) -> GPState:
    """Layout dispatch: the legacy single-population body (bitwise the
    pre-island path) or the island-batched body."""
    if cfg.island.islands > 1:
        return _island_step_body(cfg, state, X, y, weight)
    return _step_body(cfg, state, X, y, weight)


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(1,))
def evolve_step(cfg: GPConfig, state: GPState, X, y, weight=None) -> GPState:
    """One generation on a single device. X: [F, D] feature-major, y: [D];
    `weight` (f32[D] or None) masks dataset-padding points out of fitness.
    Island-batched states ([I, ...] leaves, cfg.island.islands > 1) run
    the island body; the classic layout runs the legacy body bitwise."""
    return _step_body_any(cfg, state, X, y, weight)


def _counter_row(cfg: GPConfig, state: GPState, done=None, *, mesh=False,
                 n_pods: int = 1):
    """int32[C] telemetry row for ONE scanned generation (columns:
    repro.obs.counters), computed from the PRE-step state — the same
    quantities the step body is about to consume, so the cache-hit gate
    CSEs with the step's own and the row costs a handful of scalar ops.
    Computed UNCONDITIONALLY: the compiled block program is identical
    whether anyone reads the counters, which is what pins telemetry
    on/off to bitwise-identical trajectories with zero recompiles.

    `done` is the block's freeze predicate for this step (None = the
    block can never freeze); a frozen step reports
    [0, 0, 1, 0, 0, 0, 0] — its compute ran and was discarded. With
    `mesh=True` every quantity is replicated across shards (cache AND
    dedup columns are 0 there: the elite cache is host/single-device
    machinery, and re-running the dedup signature sort per shard purely
    for telemetry would double the mesh's plan cost) so the counter
    stream's out_spec is P(); `n_pods` sizes the classic mesh pod-ring
    migration count.

    The dedup columns (SUBTREE_EVALS_SAVED, UNIQUE_SUBTREES) recompute
    `eval.dedup_stats` on the PRE-step population — unconditional given
    cfg (static), so telemetry on/off stays bitwise with no recompile
    and no extra host sync, the PR-9 pins. They are 0 when
    cfg.dedup == "off", on non-postfix genomes, and on overflow (the
    eval path then ran the plain interpreter)."""
    I = cfg.island.islands
    island = I > 1
    zero = jnp.asarray(0, jnp.int32)
    E = 0 if mesh else state.cache_op.shape[1 if island else 0]
    if not E:
        hit, queries = zero, zero
    elif island:
        hit = (jnp.all(state.op[:, :E] == state.cache_op)
               & jnp.all(state.arg[:, :E] == state.cache_arg)).astype(jnp.int32)
        queries = jnp.asarray(1, jnp.int32)
    else:
        hit = (jnp.all(state.op[:E] == state.cache_op)
               & jnp.all(state.arg[:E] == state.cache_arg)).astype(jnp.int32)
        queries = jnp.asarray(1, jnp.int32)
    # tree evaluations this generation (cache-served rows excluded);
    # the host multiplies by the dataset row count for trees·rows
    evals = jnp.asarray(I * cfg.pop_size, jnp.int32) - hit * (I * E)
    if island and cfg.island.migrate_k:
        due = ((state.generation % cfg.island.migrate_every)
               == (cfg.island.migrate_every - 1))
        migrations = jnp.where(due, I, 0).astype(jnp.int32)
    elif (not island) and mesh and n_pods > 1:
        due = ((state.generation % cfg.migrate_every)
               == (cfg.migrate_every - 1))
        migrations = jnp.where(due, n_pods, 0).astype(jnp.int32)
    else:
        migrations = zero
    if mesh or cfg.dedup == "off" or cfg.tree_spec.genome != "postfix":
        saved = uniq = zero
    else:
        from repro.core.eval import dedup_stats, resolve_dedup_cap

        N = cfg.tree_spec.num_nodes
        o = state.op.reshape(-1, N)
        a = state.arg.reshape(-1, N)
        cap = resolve_dedup_cap(cfg.dedup_cap, o.shape[0], N)
        uniq, saved = dedup_stats(o, a, cfg.tree_spec, cap)
    row = jnp.stack([hit, queries, zero, migrations, evals, saved, uniq])
    if done is None:
        return row
    return jnp.where(done, jnp.asarray([0, 0, 1, 0, 0, 0, 0], jnp.int32), row)


def _block_done(cfg: GPConfig, state: GPState, i, limit):
    """Branch-free freeze predicate for step `i` of a block: True once
    `best_fitness` has reached `cfg.stop_fitness` (on-device early stop;
    the min across islands for island-batched state) or `i` has reached
    the dynamic `limit` (a traced step budget that lets ONE compiled
    fixed-length block program serve ragged block boundaries —
    checkpoint/callback phases, final partial blocks — without
    recompiling per distinct length)."""
    done = jnp.asarray(False)
    if cfg.stop_fitness is not None:
        best = state.best_fitness
        if best.ndim:  # island-batched: any island reaching the bar stops
            best = best.min()
        done = best <= cfg.stop_fitness
    if limit is not None:
        done = done | (i >= limit)
    return done


def _freeze(done, prev: GPState, new: GPState) -> GPState:
    """Carry `prev` through unchanged (PRNG key and generation counter
    included) when `done` — frozen steps are no-ops, so the host reads
    how many generations actually ran off `state.generation`."""
    return jax.tree.map(lambda p, n: jnp.where(done, p, n), prev, new)


@partial(jax.jit, static_argnames=("cfg", "n_steps"), donate_argnums=(1,))
def evolve_block(cfg: GPConfig, state: GPState, X, y, weight=None, limit=None, *,
                 n_steps: int = 1):
    """Run up to `n_steps` generations in ONE device dispatch via `lax.scan`.

    Returns (state, history, counters) where history is the
    per-generation `best_fitness` stream — f32[n_steps] for the classic
    layout, f32[n_steps, I] (one column per island) for island-batched
    state — and counters is the int32[n_steps, C] telemetry stream
    (repro.obs.counters: cache hits/queries, frozen steps, migrations,
    tree evals), so the block's metrics ride back with the state instead
    of forcing a host sync per generation. Steps freeze into no-ops once
    `cfg.stop_fitness` is reached or the step index hits `limit`
    (dynamic int32; None = run all `n_steps`), so one compiled program
    covers every block length ≤ n_steps. The freeze is a branch-free
    select, not a skip: frozen steps still execute the generation's
    compute and discard it (a frozen step's migrations are discarded
    with it) — callers bound the waste by choosing n_steps (GPSession
    caps it at the configured period, or _STOP_CHECK_SPAN when only
    stop_fitness is armed)."""

    can_freeze = cfg.stop_fitness is not None or limit is not None

    def body(s, i):
        nxt = _step_body_any(cfg, s, X, y, weight)
        done = _block_done(cfg, s, i, limit)
        row = _counter_row(cfg, s, done if can_freeze else None)
        if can_freeze:
            nxt = _freeze(done, s, nxt)
        return nxt, (nxt.best_fitness, row)

    state, (history, counters) = jax.lax.scan(body, state, jnp.arange(n_steps))
    return state, history, counters


def run(cfg: GPConfig, X, y, key=None, generations: int | None = None,
        callback=None, seeds=None, feature_names=None) -> GPState:
    """DEPRECATED — thin forwarder to :class:`repro.gp.GPSession`, kept so
    pre-session callers don't break. X is feature-major [F, D] (the old
    contract); the session's own `fit` takes row-major data."""
    warnings.warn(
        "repro.core.run is deprecated; use repro.gp.GPSession "
        "(session = GPSession(cfg); session.fit(X_rows, y)) instead",
        DeprecationWarning, stacklevel=2)
    from repro.gp import GPSession

    sess = GPSession(cfg, feature_names=feature_names, callback=callback)
    sess.ingest(X, y, layout="features")
    sess.init(key=key, seeds=seeds)
    sess.evolve(generations)
    return sess.state


# --- multi-tenant step (repro.service) ----------------------------------------


class TenantParams(NamedTuple):
    """Per-slot search/termination parameters of a multi-tenant batch.
    Every leaf is [I]-leading and TRACED — admission and eviction at
    block boundaries rebind values on the same compiled program, so a
    long-lived service never recompiles as jobs come and go. The only
    static knobs of a tenant block are the shared shapes (`TreeSpec`,
    pop_size, data capacity), the kernel tuple `lax.switch` branches
    over, the tournament DRAW size (the random draw's shape — per-slot
    `tourn` masks down from it, `core/evolve.tournament`) and elitism.

        probs       f32[I, 4]   operator-mix probabilities per slot
        tourn       int32[I]    active tournament size (≤ the draw size)
        point_rate  f32[I]      point-mutation rate
        kernel_id   int32[I]    index into the block's static kernel tuple
        n_classes   f32[I]      classify arity (unused by other kernels)
        precision   f32[I]      match tolerance (unused by other kernels)
        stop        f32[I]      stop_fitness; -inf disables early stop
        budget      int32[I]    generation budget; 0 marks an EMPTY slot
    """

    probs: jax.Array
    tourn: jax.Array
    point_rate: jax.Array
    kernel_id: jax.Array
    n_classes: jax.Array
    precision: jax.Array
    stop: jax.Array
    budget: jax.Array


class TenantState(NamedTuple):
    """Island-batched engine state for a multi-tenant batch: the GPState
    island layout with the shared lockstep `generation` scalar replaced
    by per-slot `gens_done` counters — tenants start, stop and swap out
    independently, so no scalar is shared across slots and
    `islands.take_island`/`splice_island` move a whole job's evolution
    state in ONE slice.

        key           uint32[I, 2]    per-slot PRNG (a solo run's stream)
        op/arg        int32[I, P, N]
        fitness       f32[I, P]
        best_op/arg   int32[I, N]
        best_fitness  f32[I]
        gens_done     int32[I]
        cache_op/arg  int32[I, E, N]  per-slot elite fitness cache
        cache_fit     f32[I, E]       (same contract as GPState's)
    """

    key: jax.Array
    op: jax.Array
    arg: jax.Array
    fitness: jax.Array
    best_op: jax.Array
    best_arg: jax.Array
    best_fitness: jax.Array
    gens_done: jax.Array
    cache_op: jax.Array
    cache_arg: jax.Array
    cache_fit: jax.Array


def tenant_active(state: TenantState, params: TenantParams):
    """bool[I]: which slots still evolve — budget not exhausted AND the
    early-stop bar (params.stop, -inf = disabled) not reached. Works on
    device arrays and host numpy alike."""
    return (state.gens_done < params.budget) & jnp.logical_not(
        state.best_fitness <= params.stop)


def _tenant_cache_width(elitism: int, pop_size: int, elite_cache: bool) -> int:
    """cache_width for the tenant batch (elitism is the block's shared
    static; the same guard as the session engine's)."""
    return elitism if (elite_cache and 0 < elitism < pop_size) else 0


def init_tenant_slot(key, pop_size: int, spec: TreeSpec, elitism: int = 1,
                     elite_cache: bool = True) -> TenantState:
    """ONE job's fresh sub-state (un-batched leaves, ready for
    `islands.splice_island`). Keyed exactly like `init_state` with
    islands == 1 — split once, population from the second half, slot key
    from the first — so a packed job replays a solo session's PRNG
    stream bit-for-bit. `elitism`/`elite_cache` size the slot's elite
    fitness cache and must match the block's."""
    k0, k1 = jax.random.split(key)
    op, arg = generate_population(k1, pop_size, spec)
    N = spec.num_nodes
    E = _tenant_cache_width(elitism, pop_size, elite_cache)
    return TenantState(
        key=k0, op=op, arg=arg,
        fitness=jnp.full((pop_size,), jnp.inf, jnp.float32),
        best_op=jnp.zeros((N,), jnp.int32), best_arg=jnp.zeros((N,), jnp.int32),
        best_fitness=jnp.asarray(jnp.inf, jnp.float32),
        gens_done=jnp.asarray(0, jnp.int32),
        cache_op=jnp.zeros((E, N), jnp.int32),
        cache_arg=jnp.zeros((E, N), jnp.int32),
        cache_fit=jnp.full((E,), jnp.inf, jnp.float32),
    )


def empty_tenant_state(islands: int, pop_size: int, spec: TreeSpec,
                       elitism: int = 1,
                       elite_cache: bool = True) -> TenantState:
    """An all-empty batch (pair with budget-0 TenantParams rows: empty
    slots never advance; their compute is frozen out)."""
    I, P, N = islands, pop_size, spec.num_nodes
    E = _tenant_cache_width(elitism, pop_size, elite_cache)
    return TenantState(
        key=jnp.zeros((I, 2), jnp.uint32),
        op=jnp.zeros((I, P, N), jnp.int32), arg=jnp.zeros((I, P, N), jnp.int32),
        fitness=jnp.full((I, P), jnp.inf, jnp.float32),
        best_op=jnp.zeros((I, N), jnp.int32), best_arg=jnp.zeros((I, N), jnp.int32),
        best_fitness=jnp.full((I,), jnp.inf, jnp.float32),
        gens_done=jnp.zeros((I,), jnp.int32),
        cache_op=jnp.zeros((I, E, N), jnp.int32),
        cache_arg=jnp.zeros((I, E, N), jnp.int32),
        cache_fit=jnp.full((I, E), jnp.inf, jnp.float32),
    )


def _switch_fitness(kernels: tuple, preds, y, w, kernel_id, n_classes, precision):
    """f32[P] fitness of one slot's predictions under its TRACED kernel
    choice: `lax.switch` over the block's static kernel tuple, each
    branch the registered kernel's whole-dataset `partial_fitness` fed a
    duck-typed spec whose n_classes/precision are traced f32 — the
    kernels only consume them inside jnp ops, so one compiled program
    serves every per-slot value."""
    import types

    duck = types.SimpleNamespace(n_classes=n_classes, precision=precision)
    branches = [partial(lambda kern, p, yy, ww: kern.partial_fitness(p, yy, ww, duck),
                        fit.get_kernel(name)) for name in kernels]
    return jax.lax.switch(kernel_id, branches, preds, y, w)


def _tenant_slot_step(spec: TreeSpec, kernels: tuple, tourn_draw: int,
                      elitism: int, sub: TenantState, Xi, yi, wi,
                      p: TenantParams, dedup: str = "off",
                      dedup_cap: int = 0) -> TenantState:
    """One generation of ONE slot — deliberately the solo `_step_body`
    re-derived on un-batched leaves (evaluate → whole-dataset fitness →
    champion → split/breed → freeze), because the tenant batch runs it
    under `lax.map`, whose scan body traces this function UN-vmapped:
    the compiled reductions are the ones a solo `islands=1` session
    runs, so packed-vs-solo parity is bitwise, not just approximate
    (vmap would re-lower the fitness reductions batched and change f32
    rounding). The freeze predicate is computed on the PRE-step state,
    matching `_block_done`; a frozen (done or empty) slot's step
    computes and discards, like every freeze in this engine."""
    from repro.core.eval import (evaluate_population,
                                 evaluate_population_dedup, resolve_dedup_cap)

    active = tenant_active(sub, p)
    const_table = spec.const_table()
    use_dedup = dedup != "off" and spec.genome == "postfix"

    def eval_rows(o, a):  # f32[rows]; row-independent, so slicing is exact
        if use_dedup:
            # each slice dedups independently — bitwise equal to the
            # plain interpreter on the same rows, so packed-vs-solo and
            # dedup-on-vs-off parity both stay bitwise
            cap = resolve_dedup_cap(dedup_cap, o.shape[0], o.shape[1])
            preds = evaluate_population_dedup(o, a, Xi, const_table, spec, cap)
        else:
            preds = evaluate_population(o, a, Xi, const_table, spec)
        return _switch_fitness(kernels, preds, yi, wi, p.kernel_id,
                               p.n_classes, p.precision)

    E = sub.cache_op.shape[0]
    if E:
        hit = (jnp.all(sub.op[:E] == sub.cache_op)
               & jnp.all(sub.arg[:E] == sub.cache_arg))
        tail = eval_rows(sub.op[E:], sub.arg[E:])
        head = jax.lax.cond(hit, lambda: sub.cache_fit,
                            lambda: eval_rows(sub.op[:E], sub.arg[:E]))
        fitness = jnp.concatenate([head, tail])
    else:
        fitness = eval_rows(sub.op, sub.arg)
    i = jnp.argmin(fitness)
    improved = fitness[i] < sub.best_fitness
    best_op = jnp.where(improved, sub.op[i], sub.best_op)
    best_arg = jnp.where(improved, sub.arg[i], sub.best_arg)
    best_fit = jnp.minimum(fitness[i], sub.best_fitness)

    if E:
        # the tenant breeder selects elites on RAW fitness, so the next
        # cache is argsort(fitness)[:E] of the evaluated population
        best = jnp.argsort(fitness)[:E]
        cache_op, cache_arg = sub.op[best], sub.arg[best]
        cache_fit = fitness[best]
    else:
        cache_op, cache_arg, cache_fit = (sub.cache_op, sub.cache_arg,
                                          sub.cache_fit)

    breed = ev.make_island_breeder(spec, tourn_draw, elitism)
    key, new_op, new_arg = breed(sub.key, sub.op, sub.arg, fitness,
                                 p.probs, p.tourn, p.point_rate)
    nxt = TenantState(key, new_op, new_arg, fitness, best_op, best_arg,
                      best_fit, sub.gens_done + 1, cache_op, cache_arg,
                      cache_fit)
    return jax.tree.map(lambda prev, new: jnp.where(active, new, prev), sub, nxt)


def tenant_step(spec: TreeSpec, kernels: tuple, tourn_draw: int, elitism: int,
                state: TenantState, X, y, weight,
                params: TenantParams, dedup: str = "off",
                dedup_cap: int = 0) -> TenantState:
    """One generation of the whole batch: `lax.map` of the slot step over
    the island axis. X f32[I, F, Dc], y f32[I, Dc], weight f32[I, Dc] —
    every slot carries its OWN (padded, zero-weight-masked) dataset
    slice, so heterogeneous jobs never evaluate each other's data.
    `dedup`/`dedup_cap` (static) engage the exact-tier subexpression
    dedup inside each slot's evaluation — bitwise-identical results."""
    return jax.lax.map(
        lambda t: _tenant_slot_step(spec, kernels, tourn_draw, elitism, *t,
                                    dedup=dedup, dedup_cap=dedup_cap),
        (state, X, y, weight, params))


def _tenant_counter_row(state: TenantState, params: TenantParams):
    """int32[C] telemetry row for one tenant-batch generation, from the
    PRE-step state (columns: repro.obs.counters). Cache hits/queries
    count per ACTIVE slot (the per-slot gates the slot steps are about
    to take); FROZEN counts inactive slots — finished, early-stopped,
    or empty — whose compute runs and is discarded this generation;
    TREE_EVALS sums each active slot's non-cache-served rows. Computed
    unconditionally, like every counter row, so the service's
    no-recompile guarantee is untouched. The dedup columns are 0 here,
    like the cache columns on a mesh: slot steps dedup their own row
    slices under `lax.map`, and re-running the signature sort per slot
    purely for telemetry would double the batch's plan cost."""
    E = state.cache_op.shape[1]
    P_ = state.op.shape[1]
    a32 = tenant_active(state, params).astype(jnp.int32)
    if E:
        h32 = (jnp.all(state.op[:, :E] == state.cache_op, axis=(1, 2))
               & jnp.all(state.arg[:, :E] == state.cache_arg,
                         axis=(1, 2))).astype(jnp.int32)
        hits = (h32 * a32).sum()
        queries = a32.sum()
    else:
        h32 = jnp.zeros_like(a32)
        hits = queries = jnp.asarray(0, jnp.int32)
    frozen = (1 - a32).sum()
    evals = (a32 * (P_ - h32 * E)).sum()
    zero = jnp.asarray(0, jnp.int32)
    return jnp.stack([hits, queries, frozen, zero, evals, zero, zero])


def build_tenant_block(spec: TreeSpec, kernels: tuple, tourn_draw: int,
                       elitism: int, n_steps: int, *, dedup: str = "off",
                       dedup_cap: int = 0):
    """The service's ONE compiled program: block(state, X, y, weight,
    params) -> (state, history f32[n_steps, I], counters
    int32[n_steps, C]) scanning `tenant_step` `n_steps` generations per
    dispatch — the counter stream (repro.obs.counters) rides back with
    the same dispatch. Everything per-job is a traced operand
    (TenantParams + the slot data buffers), so the scheduler splices
    jobs in and out between dispatches without recompiling. Kernel
    names are canonicalized (aliases collapse) at build time; jit it
    with donate_argnums=(0,) — the caller owns that."""
    kernels = tuple(fit.get_kernel(k).name for k in kernels)
    for name in kernels:
        if fit.get_kernel(name).partial_fitness is None:
            raise ValueError(f"fitness kernel {name!r} has no whole-dataset "
                             f"partial_fitness; the tenant block cannot "
                             f"switch over it")

    def block(state: TenantState, X, y, weight, params: TenantParams):
        def body(s, _):
            row = _tenant_counter_row(s, params)
            nxt = tenant_step(spec, kernels, tourn_draw, elitism, s, X, y,
                              weight, params, dedup=dedup,
                              dedup_cap=dedup_cap)
            return nxt, (nxt.best_fitness, row)

        st, (hist, counters) = jax.lax.scan(body, state, None,
                                            length=n_steps)
        return st, hist, counters

    return block


# --- mesh-sharded step --------------------------------------------------------


def _merge_moments_on_mesh(kern, fit_spec, partial_m, y, weight, data_axis,
                           n_data: int):
    """Complete phase 1 across the mesh data axis WITHOUT finalizing:
    per-shard moment partials f32[P*, M] → globally merged moments
    f32[P*, M], replicated on every data shard. `_reduce_moments_on_mesh`
    finalizes for the generation step; the streaming fold
    (`build_stream_fold`) instead merges each chunk's result into a
    carried accumulator and finalizes once at end of stream.

    Three lowerings, picked by the kernel's protocol surface:

      plain sum          `lax.psum` of the full [P*, M] payload — the
                         classic path, bitwise what it always was for
                         decomposable kernels.
      + y-hoisting       the tree-independent columns (`y_moment_idx`,
                         identical on every row) ride ONCE per shard:
                         psum [P*, Mt] + [My] instead of [P*, M] — for
                         pearson that is ~half the reduction bytes.
      pairwise combine   kernels with a non-additive merge (centered
                         moments + Chan combine): `all_gather` the
                         per-shard partials and fold with
                         `combine_moments` — n_data is small and the
                         payload already shrank via hoisting.
    """
    if kern.combine_moments is None:
        if not kern.y_moment_idx:
            return jax.lax.psum(partial_m, data_axis)
        t_idx = jnp.asarray(kern.tree_moment_idx)
        tree_m = jax.lax.psum(partial_m[..., t_idx], data_axis)
        y_m = jax.lax.psum(kern.y_moments(y, weight, fit_spec), data_axis)
        return fit.scatter_tree_y(kern, tree_m, y_m)
    if kern.y_moment_idx:
        t_idx = jnp.asarray(kern.tree_moment_idx)
        # row 0's y-columns == every row's (tree-independent by contract)
        tree_parts = jax.lax.all_gather(partial_m[..., t_idx], data_axis)
        y_parts = jax.lax.all_gather(
            partial_m[0, jnp.asarray(kern.y_moment_idx)], data_axis)
        parts = [fit.scatter_tree_y(kern, tree_parts[s], y_parts[s])
                 for s in range(n_data)]
    else:
        gathered = jax.lax.all_gather(partial_m, data_axis)
        parts = [gathered[s] for s in range(n_data)]
    return fit.fold_moment_partials(kern, parts, fit_spec)


def _reduce_moments_on_mesh(kern, fit_spec, partial_m, y, weight, data_axis,
                            n_data: int):
    """Complete phase 1 across the mesh data axis and finalize: per-shard
    moment partials f32[P*, M] → fitness f32[P*] (replicated). See
    `_merge_moments_on_mesh` for the three reduction lowerings."""
    return kern.reduce_moments(
        _merge_moments_on_mesh(kern, fit_spec, partial_m, y, weight,
                               data_axis, n_data), fit_spec)


def _sharded_step_builder(cfg: GPConfig, mesh, *, data_axis="data",
                          model_axis="model", pod_axis: str | None = None):
    """Per-shard generation-step body + its PartitionSpecs — the common
    core of `sharded_evolve_step` (one step per dispatch) and
    `sharded_evolve_block` (K steps per dispatch via an in-shard_map
    scan). Returns (step, state_specs, data_spec, y_spec, w_spec)."""
    from repro.core.islands import migrate

    kern = fit.get_kernel(cfg.fitness.kernel)
    if kern.moments is None:
        raise ValueError(
            f"fitness kernel {kern.name!r} defines no moment pass "
            f"(moments/reduce_moments), so nothing can be psum-reduced across "
            f"the {data_axis!r} axis; register it through the two-pass protocol "
            f"(see docs/fitness-kernels.md) or run single-device")

    pod_dims = (pod_axis,) if pod_axis else ()
    n_shards = mesh.shape[model_axis]
    for a in pod_dims:
        n_shards *= mesh.shape[a]
    if cfg.pop_size % n_shards:
        raise ValueError(f"pop_size {cfg.pop_size} % population shards {n_shards} != 0")
    n_model = mesh.shape[model_axis]

    pop_spec = P((*pod_dims, model_axis))
    data_spec = P(None, data_axis)  # X is [F, D]
    y_spec = P(data_axis)
    w_spec = P(data_axis)  # padding mask rides the same axis as y
    state_specs = GPState(
        key=P(), op=pop_spec, arg=pop_spec, fitness=pop_spec,
        best_op=P(), best_arg=P(), best_fitness=P(), generation=P(),
        # the elite cache is host/single-device machinery: mesh steps carry
        # it through replicated and untouched (they re-seed elites via the
        # rank-0 champion row, not the [:E] convention the cache keys on)
        cache_op=P(), cache_arg=P(), cache_fit=P(),
    )

    n_data = mesh.shape[data_axis]

    def step(state: GPState, X, y, weight) -> GPState:
        const_table = cfg.tree_spec.const_table()
        # --- evaluate, two passes: local pop shard x local data shard
        # emits weighted moments; the data-axis reduction completes
        # phase 1 (psum, hoisted psum, or combine-fold — see
        # _reduce_moments_on_mesh) and reduce_moments finalizes — for
        # decomposable kernels M == 1 and this degenerates to the
        # classic psum-of-partials
        partial_m = _eval_moments(cfg, state.op, state.arg, X, y, weight,
                                  const_table)
        fitness_local = _reduce_moments_on_mesh(kern, cfg.fitness, partial_m,
                                                y, weight, data_axis, n_data)
        # --- selection pool = this pod's population: tiny all_gather
        fitness_g = jax.lax.all_gather(fitness_local, model_axis, tiled=True)
        op_g = jax.lax.all_gather(state.op, model_axis, tiled=True)
        arg_g = jax.lax.all_gather(state.arg, model_axis, tiled=True)

        # --- pod-local best, then global best across pods (replicated)
        i = jnp.argmin(fitness_g)
        cand_fit, cand_op, cand_arg = fitness_g[i], op_g[i], arg_g[i]
        if pod_axis:
            pods_fit = jax.lax.all_gather(cand_fit, pod_axis)  # [n_pods]
            pods_op = jax.lax.all_gather(cand_op, pod_axis)  # [n_pods, N]
            pods_arg = jax.lax.all_gather(cand_arg, pod_axis)
            j = jnp.argmin(pods_fit)
            cand_fit, cand_op, cand_arg = pods_fit[j], pods_op[j], pods_arg[j]
        improved = cand_fit < state.best_fitness
        best_op = jnp.where(improved, cand_op, state.best_op)
        best_arg = jnp.where(improved, cand_arg, state.best_arg)
        best_fit = jnp.minimum(cand_fit, state.best_fitness)

        # --- offspring for this shard's slice only (decorrelated RNG)
        rank = jax.lax.axis_index(model_axis)
        key = state.key
        if pod_axis:
            key = jax.random.fold_in(key, jax.lax.axis_index(pod_axis))
        key = jax.random.fold_in(key, state.generation)
        k_rank = jax.random.fold_in(key, rank)
        n_local = cfg.pop_size // n_shards
        new_op, new_arg = ev.next_generation(
            k_rank, op_g, arg_g, fitness_g, cfg.tree_spec, cfg.mix,
            cfg.tourn_size, elitism=0, n_out=n_local)
        # elitism: rank 0 of each pod re-seeds the pod's own champion
        if cfg.elitism:
            keep = rank == 0
            new_op = new_op.at[0].set(jnp.where(keep, op_g[i], new_op[0]))
            new_arg = new_arg.at[0].set(jnp.where(keep, arg_g[i], new_arg[0]))
        if pod_axis:
            order = jnp.argsort(fitness_g)[:cfg.migrate_k]
            new_op, new_arg = migrate(
                cfg, new_op, new_arg, op_g[order], arg_g[order],
                state.generation, pod_axis, is_receiver=rank == n_model - 1)
        return GPState(state.key, new_op, new_arg, fitness_local, best_op, best_arg,
                       best_fit, state.generation + 1,
                       state.cache_op, state.cache_arg, state.cache_fit)

    return step, state_specs, data_spec, y_spec, w_spec


def _sharded_island_step_builder(cfg: GPConfig, mesh, *, data_axis="data",
                                 model_axis="model", pod_axis: str | None = None):
    """Per-shard generation step for the ISLAND-BATCHED layout
    (cfg.island.islands = I > 1): the global state is `op int32[I, P, N]`
    with the island axis sharded over the pod axis (I_local = I / n_pods
    islands per pod) and each island's population sharded over the model
    axis — pods × in-device islands from one builder. Evaluation flattens
    the local islands into one backend call; selection + breeding vmap
    over the island axis with per-island operator parameters; migration
    is the composed lowering (in-device roll + pod-boundary ppermute,
    islands.migrate_sharded). Returns the same tuple contract as the
    legacy builder."""
    from repro.core import islands as isl

    icfg = cfg.island
    I = icfg.islands
    kern = fit.get_kernel(cfg.fitness.kernel)
    if kern.moments is None:
        raise ValueError(
            f"fitness kernel {kern.name!r} defines no moment pass "
            f"(moments/reduce_moments), so nothing can be reduced across "
            f"the {data_axis!r} axis; register it through the two-pass protocol "
            f"(see docs/fitness-kernels.md) or run single-device")

    n_pods = mesh.shape[pod_axis] if pod_axis else 1
    if I % n_pods:
        raise ValueError(f"islands {I} % pod axis {n_pods} != 0 — the pod "
                         f"axis shards whole islands")
    n_model = mesh.shape[model_axis]
    if cfg.pop_size % n_model:
        raise ValueError(f"per-island pop_size {cfg.pop_size} % model axis "
                         f"{n_model} != 0")
    n_local = cfg.pop_size // n_model
    if icfg.migrate_k > n_local:
        raise ValueError(f"migrate_k {icfg.migrate_k} exceeds the last model "
                         f"rank's {n_local}-tree slice that receives migrants")
    n_data = mesh.shape[data_axis]

    pod = pod_axis  # None → replicated island axis (in-device islands only)
    pop_spec = P(pod, model_axis, None)
    data_spec = P(None, data_axis)  # X is [F, D]
    y_spec = P(data_axis)
    w_spec = P(data_axis)
    state_specs = GPState(
        key=P(pod, None), op=pop_spec, arg=pop_spec,
        fitness=P(pod, model_axis),
        best_op=P(pod, None), best_arg=P(pod, None),
        best_fitness=P(pod), generation=P(),
        # cache rides the island (pod) axis, untouched by the mesh step
        cache_op=P(pod, None, None), cache_arg=P(pod, None, None),
        cache_fit=P(pod, None),
    )
    probs_t, tourn_max, tourn_t, pp_t = _island_tables(cfg)

    def step(state: GPState, X, y, weight) -> GPState:
        const_table = cfg.tree_spec.const_table()
        Il, Pl, N = state.op.shape  # per-shard: I_local, pop/model, nodes
        partial_m = _eval_moments(cfg, state.op.reshape(Il * Pl, N),
                                  state.arg.reshape(Il * Pl, N), X, y, weight,
                                  const_table)
        fitness_local = _reduce_moments_on_mesh(
            kern, cfg.fitness, partial_m, y, weight, data_axis,
            n_data).reshape(Il, Pl)
        # --- selection pool = each island's own population: tiny gathers
        fitness_g = jax.lax.all_gather(fitness_local, model_axis, axis=1,
                                       tiled=True)  # [Il, P]
        op_g = jax.lax.all_gather(state.op, model_axis, axis=1, tiled=True)
        arg_g = jax.lax.all_gather(state.arg, model_axis, axis=1, tiled=True)

        # --- per-island champion (each pod owns its islands' streams)
        i = jnp.argmin(fitness_g, axis=1)  # [Il]
        rows = jnp.arange(Il)
        cand_fit, cand_op, cand_arg = (fitness_g[rows, i], op_g[rows, i],
                                       arg_g[rows, i])
        improved = cand_fit < state.best_fitness
        best_op = jnp.where(improved[:, None], cand_op, state.best_op)
        best_arg = jnp.where(improved[:, None], cand_arg, state.best_arg)
        best_fit = jnp.minimum(cand_fit, state.best_fitness)

        sel_fitness = fitness_g
        if cfg.parsimony:
            from repro.core.trees import tree_sizes

            sizes = tree_sizes(op_g.reshape(Il * cfg.pop_size, N))
            sel_fitness = fitness_g + cfg.parsimony * sizes.reshape(
                Il, cfg.pop_size).astype(jnp.float32)

        # --- offspring for this shard's slice (decorrelated per island
        # via the per-island key, per rank via fold_in); per-island
        # search parameters are the pod's slice of the global tables
        rank = jax.lax.axis_index(model_axis)
        start = (jax.lax.axis_index(pod) if pod else 0) * Il
        probs_l = jax.lax.dynamic_slice_in_dim(jnp.asarray(probs_t), start, Il, 0)
        tourn_l = jax.lax.dynamic_slice_in_dim(jnp.asarray(tourn_t), start, Il, 0)
        pp_l = jax.lax.dynamic_slice_in_dim(jnp.asarray(pp_t), start, Il, 0)

        breed = ev.make_island_breeder(cfg.tree_spec, tourn_max, elitism=0,
                                       n_out=n_local, fold=rank)
        keys, new_op, new_arg = jax.vmap(breed)(
            state.key, op_g, arg_g, sel_fitness, probs_l, tourn_l, pp_l)
        # elitism: rank 0's slice re-seeds each island's own champion
        if cfg.elitism:
            keep = rank == 0
            new_op = new_op.at[:, 0].set(
                jnp.where(keep, cand_op, new_op[:, 0]))
            new_arg = new_arg.at[:, 0].set(
                jnp.where(keep, cand_arg, new_arg[:, 0]))
        if icfg.migrate_k and I > 1:
            e_op, e_arg = isl.island_elites(op_g, arg_g, fitness_g,
                                            icfg.migrate_k)
            new_op, new_arg = isl.migrate_sharded(
                icfg, new_op, new_arg, e_op, e_arg, state.generation,
                cand_fit, pod, is_receiver=rank == n_model - 1)
        return GPState(keys, new_op, new_arg, fitness_local, best_op, best_arg,
                       best_fit, state.generation + 1,
                       state.cache_op, state.cache_arg, state.cache_fit)

    return step, state_specs, data_spec, y_spec, w_spec


def _pick_step_builder(cfg: GPConfig):
    return (_sharded_island_step_builder if cfg.island.islands > 1
            else _sharded_step_builder)


def sharded_evolve_step(cfg: GPConfig, mesh, *, data_axis="data", model_axis="model",
                        pod_axis: str | None = None):
    """Build a shard_map'd generation step for `mesh`.

    Shardings: X, y, weight on (data,). Classic layout (islands == 1):
    the population's leading axis is on (pod, model) — the pod slices
    are the islands, the model slices are a pod's parallel evaluation
    shards — and best_* is replicated (global argmin over pods).
    Island-batched layout (cfg.island.islands = I > 1): the state's
    leading ISLAND axis is on (pod,), each island's population on
    (model,), and best_* is per island ([I, ...], sharded over pod).
    Returns (step_fn, specs dict) ready for jit/lower;
    step_fn(state, X, y, weight) — weight is the f32[D] dataset-padding
    mask (all-ones when nothing was padded).
    """
    step, state_specs, data_spec, y_spec, w_spec = _pick_step_builder(cfg)(
        cfg, mesh, data_axis=data_axis, model_axis=model_axis, pod_axis=pod_axis)
    smapped = compat.shard_map(
        step, mesh=mesh,
        in_specs=(state_specs, data_spec, y_spec, w_spec),
        out_specs=state_specs,
    )
    return smapped, dict(state=state_specs, X=data_spec, y=y_spec, weight=w_spec)


def sharded_evolve_block(cfg: GPConfig, mesh, *, n_steps: int, data_axis="data",
                         model_axis="model", pod_axis: str | None = None):
    """Build a shard_map'd K-generation evolution block for `mesh`.

    The `lax.scan` lives INSIDE shard_map, so one dispatch runs `n_steps`
    generations — collectives included — with no host round-trip between
    them. Early stop follows the same branch-free freeze as the
    single-device block; the classic layout's `best_fitness` is
    replicated, the island layout reduces it (min over the pod's local
    islands, `pmin` over the pod axis), so every shard takes the same
    freeze decision either way. Returns (block_fn, specs dict);
    block_fn(state, X, y, weight, limit) -> (state, history, counters) —
    `limit` is the replicated dynamic step budget (pass n_steps to run
    the full block); history is f32[n_steps] replicated for the classic
    layout, f32[n_steps, I] (one per-island best-fitness stream per
    column, sharded over pod) for the island layout; counters is the
    replicated int32[n_steps, C] telemetry stream (repro.obs.counters —
    cache columns are 0 on a mesh).
    """
    island = cfg.island.islands > 1
    n_pods = mesh.shape[pod_axis] if pod_axis else 1
    step, state_specs, data_spec, y_spec, w_spec = _pick_step_builder(cfg)(
        cfg, mesh, data_axis=data_axis, model_axis=model_axis, pod_axis=pod_axis)

    def done(s, i, limit):
        if not (island and cfg.stop_fitness is not None):
            return _block_done(cfg, s, i, limit)
        best = s.best_fitness.min()  # this pod's islands
        if pod_axis:
            best = jax.lax.pmin(best, pod_axis)  # every shard agrees
        d = best <= cfg.stop_fitness
        return d if limit is None else d | (i >= limit)

    def block(state: GPState, X, y, weight, limit):
        def body(s, i):
            d = done(s, i, limit)
            row = _counter_row(cfg, s, d, mesh=True, n_pods=n_pods)
            nxt = _freeze(d, s, step(s, X, y, weight))
            return nxt, (nxt.best_fitness, row)

        st, (hist, counters) = jax.lax.scan(body, state, jnp.arange(n_steps))
        return st, hist, counters

    hist_spec = P(None, pod_axis) if island else P()
    smapped = compat.shard_map(
        block, mesh=mesh,
        in_specs=(state_specs, data_spec, y_spec, w_spec, P()),
        out_specs=(state_specs, hist_spec, P()),
    )
    return smapped, dict(state=state_specs, X=data_spec, y=y_spec, weight=w_spec,
                         limit=P(), history=hist_spec, counters=P())


# --- streaming chunked fitness ------------------------------------------------


def _stream_kernel(cfg: GPConfig):
    kern = fit.get_kernel(cfg.fitness.kernel)
    if kern.moments is None:
        raise ValueError(
            f"fitness kernel {kern.name!r} defines no moment pass "
            f"(moments/reduce_moments), so it cannot accumulate across data "
            f"chunks; register it through the two-pass protocol "
            f"(see docs/fitness-kernels.md) or evaluate monolithic")
    return kern


def chunked_moments(cfg: GPConfig, op, arg, dataset, const_table=None, *,
                    impl: str | None = None):
    """Phase-1 moments of the WHOLE streamed dataset: fold every chunk of
    `dataset` (a `data/loader.ChunkedDataset`, or any iterable of
    fixed-shape `(X_fm, y, weight)` chunks) into an f32[P, M] accumulator
    via the backend's `stream_moments` — one fixed-shape jitted dispatch
    per chunk, so peak device footprint is ONE chunk plus the
    accumulator, independent of total rows. The fold seeds with zeros
    (the kernel-merge identity by contract) and the host drives the chunk
    loop; finalize with `chunked_fitness` or `reduce_moments`."""
    from repro.gp.backends import get_backend

    backend = get_backend(impl or cfg.eval_impl)
    kern = _stream_kernel(cfg)
    if backend.stream_moments is None:
        raise ValueError(f"eval backend {backend.name!r} exposes no "
                         f"stream_moments pass and cannot fold data chunks")
    if const_table is None:
        const_table = cfg.tree_spec.const_table()
    acc = jnp.zeros((op.shape[0], kern.n_moments), jnp.float32)
    for X, y, weight in dataset:
        acc = backend.stream_moments(acc, op, arg, X, y, const_table,
                                     cfg.tree_spec, cfg.fitness, weight=weight,
                                     data_tile=cfg.data_tile)
    return acc


def chunked_fitness(cfg: GPConfig, op, arg, dataset, const_table=None, *,
                    impl: str | None = None):
    """f32[P] fitness of every tree against a chunked data stream:
    `chunked_moments` folded over the chunks, finalized ONCE by the
    kernel's `reduce_moments`. Parity with the monolithic paths is pinned
    by tests/test_stream.py — bitwise for decomposable kernels (their
    merge is an exact weighted sum of per-point terms), ≤1e-4 for the
    centered-moment kernels (pearson/r2), for ANY chunking including a
    ragged zero-weight-padded final chunk."""
    kern = _stream_kernel(cfg)
    m = chunked_moments(cfg, op, arg, dataset, const_table, impl=impl)
    return kern.reduce_moments(jnp.asarray(m), cfg.fitness)


def build_stream_fold(cfg: GPConfig, mesh, *, data_axis: str = "data"):
    """Jitted mesh fold step for streaming chunks, composing chunking
    with the data-axis shard: `fold(acc, op, arg, X, y, weight) -> acc`
    with `acc`/`op`/`arg` replicated and the chunk's `X [F, Dc]` /
    `y [Dc]` / `weight [Dc]` sharded on `data_axis` (Dc % data == 0 —
    `GPSession.ingest` rounds `chunk_rows` up). Each call completes
    phase 1 for its chunk across the mesh (`_merge_moments_on_mesh`:
    psum / hoisted psum / gather+combine, matching the generation step's
    reduction semantics) and merges the replicated result into the
    carried accumulator; finalize the final accumulator once with the
    kernel's `reduce_moments`."""
    kern = _stream_kernel(cfg)
    n_data = mesh.shape[data_axis]

    def fold(acc, op, arg, X, y, weight):
        const_table = cfg.tree_spec.const_table()
        partial_m = _eval_moments(cfg, op, arg, X, y, weight, const_table)
        merged = _merge_moments_on_mesh(kern, cfg.fitness, partial_m, y,
                                        weight, data_axis, n_data)
        return kern.merge_moments(acc, merged, cfg.fitness)

    smapped = compat.shard_map(
        fold, mesh=mesh,
        in_specs=(P(), P(), P(), P(None, data_axis), P(data_axis),
                  P(data_axis)),
        out_specs=P(),
    )
    return jax.jit(smapped)
