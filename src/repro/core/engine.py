"""KarooEngine — the generation loop, single-device and mesh-sharded.

Workflow (paper §2.4): build population → evaluate fitness → select →
apply genetic operators → repeat. Step 2 is the parallel hot spot; here it
is one jitted program per generation, and under `shard_map` it distributes
as:

    data axis   : dataset columns sharded; per-tree fitness partials are
                  `psum`-reduced (the paper's vectorized-evaluation axis)
    model axis  : population sharded; selection needs the global fitness
                  vector + parent pool, an O(pop·nodes) `all_gather` (tiny
                  next to evaluation, paper §2.3)
    pod axis    : island-model populations with periodic elite migration
                  (core/islands.py) — the multi-pod story

Engine state is a pytree, so checkpointing/restore reuses ckpt/ unchanged.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import evolve as ev
from repro.core import fitness as fit
from repro.core.trees import TreeSpec, generate_population


@dataclasses.dataclass(frozen=True)
class GPConfig:
    """Run-time parameters (paper Table 2 defaults)."""

    name: str = "karoo"
    pop_size: int = 100
    tree_spec: TreeSpec = TreeSpec()
    fitness: fit.FitnessSpec = fit.FitnessSpec()
    mix: ev.OperatorMix = ev.OperatorMix()
    tourn_size: int = 10
    generations: int = 30
    elitism: int = 1
    parsimony: float = 0.0  # bloat pressure: selection fitness += p * size
    stop_fitness: float | None = None  # early termination threshold (run())
    eval_impl: str = "jnp"  # any jittable name in repro.gp.backends
    data_tile: int = 1024  # pallas data-tile (lane-dim multiple of 128)
    migrate_every: int = 10  # pod-axis island migration period
    migrate_k: int = 4  # elites exchanged per migration

    def __hash__(self):
        return hash((self.name, self.pop_size, self.tree_spec, self.fitness, self.mix,
                     self.tourn_size, self.generations, self.elitism, self.parsimony,
                     self.stop_fitness, self.eval_impl,
                     self.data_tile, self.migrate_every, self.migrate_k))


class GPState(NamedTuple):
    key: jax.Array
    op: jax.Array  # int32[P, N]
    arg: jax.Array  # int32[P, N]
    fitness: jax.Array  # float32[P] (of current population, minimize)
    best_op: jax.Array  # int32[N]
    best_arg: jax.Array  # int32[N]
    best_fitness: jax.Array  # float32[]
    generation: jax.Array  # int32[]


def _eval_fitness(cfg: GPConfig, op, arg, X, y, const_table):
    """Dispatch to the EvalBackend registered under `cfg.eval_impl`
    (repro.gp.backends — pallas fused kernel, jnp tiled reference, or any
    user-registered jittable backend)."""
    from repro.gp.backends import get_backend

    backend = get_backend(cfg.eval_impl)
    if not backend.jittable:
        raise ValueError(
            f"eval backend {backend.name!r} is host-only and cannot run inside "
            f"the jitted generation step; drive it through repro.gp.GPSession")
    return backend.fitness(op, arg, X, y, const_table, cfg.tree_spec, cfg.fitness,
                           data_tile=cfg.data_tile)


def init_state(cfg: GPConfig, key, seeds=None, feature_names=None) -> GPState:
    """Fresh state; `seeds` (expression strings) populate the first slots —
    Karoo's customized seed populations (paper §2.2)."""
    k0, k1 = jax.random.split(key)
    if seeds:
        from repro.core.parse import seed_population

        op, arg = seed_population(seeds, cfg.tree_spec, cfg.pop_size, k1,
                                  feature_names)
    else:
        op, arg = generate_population(k1, cfg.pop_size, cfg.tree_spec)
    N = cfg.tree_spec.num_nodes
    return GPState(
        key=k0, op=op, arg=arg,
        fitness=jnp.full((cfg.pop_size,), jnp.inf, jnp.float32),
        best_op=jnp.zeros((N,), jnp.int32), best_arg=jnp.zeros((N,), jnp.int32),
        best_fitness=jnp.asarray(jnp.inf, jnp.float32),
        generation=jnp.asarray(0, jnp.int32),
    )


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(1,))
def evolve_step(cfg: GPConfig, state: GPState, X, y) -> GPState:
    """One generation on a single device. X: [F, D] feature-major, y: [D]."""
    const_table = cfg.tree_spec.const_table()
    fitness = _eval_fitness(cfg, state.op, state.arg, X, y, const_table)
    # best tracked on RAW fitness; selection may add parsimony pressure
    i = jnp.argmin(fitness)
    improved = fitness[i] < state.best_fitness
    best_op = jnp.where(improved, state.op[i], state.best_op)
    best_arg = jnp.where(improved, state.arg[i], state.best_arg)
    best_fit = jnp.minimum(fitness[i], state.best_fitness)

    sel_fitness = fitness
    if cfg.parsimony:
        from repro.core.trees import tree_sizes

        sel_fitness = fitness + cfg.parsimony * tree_sizes(state.op).astype(jnp.float32)

    key, k_next = jax.random.split(state.key)
    new_op, new_arg = ev.next_generation(
        k_next, state.op, state.arg, sel_fitness, cfg.tree_spec, cfg.mix,
        cfg.tourn_size, cfg.elitism)
    return GPState(key, new_op, new_arg, fitness, best_op, best_arg, best_fit,
                   state.generation + 1)


def run(cfg: GPConfig, X, y, key=None, generations: int | None = None,
        callback=None, seeds=None, feature_names=None) -> GPState:
    """DEPRECATED — thin forwarder to :class:`repro.gp.GPSession`, kept so
    pre-session callers don't break. X is feature-major [F, D] (the old
    contract); the session's own `fit` takes row-major data."""
    warnings.warn(
        "repro.core.run is deprecated; use repro.gp.GPSession "
        "(session = GPSession(cfg); session.fit(X_rows, y)) instead",
        DeprecationWarning, stacklevel=2)
    from repro.gp import GPSession

    sess = GPSession(cfg, feature_names=feature_names, callback=callback)
    sess.ingest(X, y, layout="features")
    sess.init(key=key, seeds=seeds)
    sess.evolve(generations)
    return sess.state


# --- mesh-sharded step --------------------------------------------------------


def sharded_evolve_step(cfg: GPConfig, mesh, *, data_axis="data", model_axis="model",
                        pod_axis: str | None = None):
    """Build a shard_map'd generation step for `mesh`.

    Shardings: X,y on (data,); the population's leading axis on
    (pod, model) — the pod slices are the islands, the model slices are
    a pod's parallel evaluation shards. Returns (step_fn, specs dict)
    ready for jit/lower. best_* is replicated (global argmin over pods).
    """
    from repro.core.islands import migrate

    kern = fit.get_kernel(cfg.fitness.kernel)
    if not kern.decomposable:
        raise ValueError(
            f"fitness kernel {kern.name!r} is not sum-decomposable over data; "
            f"its partials cannot be psum-reduced across the {data_axis!r} axis")

    pod_dims = (pod_axis,) if pod_axis else ()
    n_shards = mesh.shape[model_axis]
    for a in pod_dims:
        n_shards *= mesh.shape[a]
    if cfg.pop_size % n_shards:
        raise ValueError(f"pop_size {cfg.pop_size} % population shards {n_shards} != 0")
    n_model = mesh.shape[model_axis]

    pop_spec = P((*pod_dims, model_axis))
    data_spec = P(None, data_axis)  # X is [F, D]
    y_spec = P(data_axis)
    state_specs = GPState(
        key=P(), op=pop_spec, arg=pop_spec, fitness=pop_spec,
        best_op=P(), best_arg=P(), best_fitness=P(), generation=P(),
    )

    def step(state: GPState, X, y) -> GPState:
        const_table = cfg.tree_spec.const_table()
        # --- evaluate: local pop shard x local data shard; psum over data
        partial_fit = _eval_fitness(cfg, state.op, state.arg, X, y, const_table)
        fitness_local = jax.lax.psum(partial_fit, data_axis)
        # --- selection pool = this pod's population: tiny all_gather
        fitness_g = jax.lax.all_gather(fitness_local, model_axis, tiled=True)
        op_g = jax.lax.all_gather(state.op, model_axis, tiled=True)
        arg_g = jax.lax.all_gather(state.arg, model_axis, tiled=True)

        # --- pod-local best, then global best across pods (replicated)
        i = jnp.argmin(fitness_g)
        cand_fit, cand_op, cand_arg = fitness_g[i], op_g[i], arg_g[i]
        if pod_axis:
            pods_fit = jax.lax.all_gather(cand_fit, pod_axis)  # [n_pods]
            pods_op = jax.lax.all_gather(cand_op, pod_axis)  # [n_pods, N]
            pods_arg = jax.lax.all_gather(cand_arg, pod_axis)
            j = jnp.argmin(pods_fit)
            cand_fit, cand_op, cand_arg = pods_fit[j], pods_op[j], pods_arg[j]
        improved = cand_fit < state.best_fitness
        best_op = jnp.where(improved, cand_op, state.best_op)
        best_arg = jnp.where(improved, cand_arg, state.best_arg)
        best_fit = jnp.minimum(cand_fit, state.best_fitness)

        # --- offspring for this shard's slice only (decorrelated RNG)
        rank = jax.lax.axis_index(model_axis)
        key = state.key
        if pod_axis:
            key = jax.random.fold_in(key, jax.lax.axis_index(pod_axis))
        key = jax.random.fold_in(key, state.generation)
        k_rank = jax.random.fold_in(key, rank)
        n_local = cfg.pop_size // n_shards
        new_op, new_arg = ev.next_generation(
            k_rank, op_g, arg_g, fitness_g, cfg.tree_spec, cfg.mix,
            cfg.tourn_size, elitism=0, n_out=n_local)
        # elitism: rank 0 of each pod re-seeds the pod's own champion
        if cfg.elitism:
            keep = rank == 0
            new_op = new_op.at[0].set(jnp.where(keep, op_g[i], new_op[0]))
            new_arg = new_arg.at[0].set(jnp.where(keep, arg_g[i], new_arg[0]))
        if pod_axis:
            order = jnp.argsort(fitness_g)[:cfg.migrate_k]
            new_op, new_arg = migrate(
                cfg, new_op, new_arg, op_g[order], arg_g[order],
                state.generation, pod_axis, is_receiver=rank == n_model - 1)
        return GPState(state.key, new_op, new_arg, fitness_local, best_op, best_arg,
                       best_fit, state.generation + 1)

    smapped = compat.shard_map(
        step, mesh=mesh,
        in_specs=(state_specs, data_spec, y_spec),
        out_specs=state_specs,
    )
    return smapped, dict(state=state_specs, X=data_spec, y=y_spec)
