"""Fitness kernels — a registry of pluggable GP objectives.

Karoo GP appends a per-kernel fitness sub-graph to each tree's TF graph;
we fuse the same reductions after the vectorized evaluation. The paper's
three kernels — (r)egression, (c)lassification, (m)atch — ship built in,
plus `mse`, `pearson` and `r2`; new objectives register a `FitnessKernel`
and every evaluation path (jnp reference, tiled reference, Pallas fused
kernel, scalar baseline) and the selection code pick them up without
modification. See docs/fitness-kernels.md for the registration guide.

Every kernel is evaluated in **two passes** so any objective — including
statistics like Pearson correlation that need global moments — works on
any data tiling and any device mesh:

  phase 1  `moments(preds, y, weight, spec)` returns weighted sufficient
           moments f32[P, M] over one data tile/shard. Partial moments
           from different tiles/shards are MERGED — by elementwise sum
           by default, or by the kernel's own associative
           `combine_moments` (jnp tiling, Pallas grid accumulation, the
           mesh data-axis reduction).
  phase 2  `reduce_moments(moments, spec)` turns the fully-merged
           f32[..., M] moments into the final f32[...] fitness.

Two refinements ride on the protocol (both optional per kernel):

  * `combine_moments` lets a kernel carry *shard-locally centered*
    moments (mean / M2 / co-moment) merged pairwise with Chan's
    parallel-variance formulas instead of raw power sums — `pearson`
    and `r2` do, which removes the classic E[x²]−E[x]² f32
    catastrophic cancellation on |mean| ≫ std targets from every
    tiled and sharded path.
  * `y_moment_idx` marks the moment columns that depend only on
    (y, weight) — identical for every tree — so reductions can carry
    them ONCE per shard instead of per tree (for `pearson` that is
    3 of 7 columns: ~half the mesh reduction bytes).

Sum-decomposable objectives (abs-error, MSE, hit counts) are the trivial
M=1 case: their single "moment" *is* the fitness partial and phase 2 is a
squeeze. Such kernels can be registered with just `partial_fitness`
(the pre-two-pass surface, kept as the convenience spelling) and the
registry derives the moment pass automatically. Conversely, a kernel
registered through `moments`/`reduce_moments` gets a derived
`partial_fitness` that computes the full fitness in one call (phase 1 +
phase 2 over the whole dataset).

Conventions every kernel obeys:

  * MINIMIZE — lower fitness is better (classify and match are negated
    hit counts), so selection code is kernel-agnostic.
  * `weight` masks data padding: points with weight 0 contribute nothing
    to any moment. Multiply by `weight` BEFORE any squaring/products so a
    padded point's garbage prediction (even ±inf) is zeroed, not NaN'd.
  * NaN sanitization — a NaN prediction at any *valid* (weight > 0)
    point makes the tree's fitness +inf. A NaN-producing tree must never
    win a tournament in ANY kernel (`round(NaN)` → int is undefined, so
    classify/match cannot just bin the prediction). Two-pass kernels
    carry an "invalid count" moment (NaN-at-valid-point occurrences, a
    plain weighted sum) and let `reduce_moments` map count > 0 → +inf.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

REGRESSION = "r"
CLASSIFY = "c"
MATCH = "m"


@dataclasses.dataclass(frozen=True)
class FitnessSpec:
    kernel: str = REGRESSION  # any name registered in the kernel registry
    n_classes: int = 3  # classify only
    precision: float = 1e-4  # match tolerance (paper: 4 decimal places)

    def __hash__(self):
        return hash((self.kernel, self.n_classes, self.precision))


@dataclasses.dataclass(frozen=True)
class FitnessKernel:
    """One pluggable objective, evaluated as moments → sum → finalize.

    All callables must be pure jnp (they also run inside the Pallas
    kernel body and under shard_map). Shapes:

      moments:         (preds f32[P, D], y f32[D], weight f32[D], spec)
                       -> f32[P, M] weighted moment partials for one data
                       tile/shard; partials from different tiles/shards
                       are merged (elementwise sum, or `combine_moments`
                       when the kernel defines one) before phase 2.
      reduce_moments:  (moments f32[..., M], spec) -> f32[...] final
                       fitness (minimize) from fully-merged moments.
      combine_moments: optional (m1 f32[..., M], m2 f32[..., M], spec)
                       -> f32[..., M] associative pairwise merge of two
                       partials. None = elementwise sum. The all-zeros
                       vector must be a merge identity (it seeds scan
                       accumulators). Lets kernels carry shard-locally
                       centered moments (Chan's parallel combine).
      y_moments:       optional (y f32[D], weight f32[D], spec) ->
                       f32[len(y_moment_idx)] — just the tree-independent
                       columns of the moment vector, for reductions that
                       hoist them out of the per-tree payload.
      y_moment_idx:    positions (tuple of ints) of the moment columns
                       that depend only on (y, weight) — every tree row
                       carries the identical value there, so sharded
                       reductions move them once, not P times.
      partial_fitness: (preds f32[P, D], y f32[D], weight f32[D], spec)
                       -> f32[P]. For `decomposable` kernels this is the
                       M=1 moment (summable across tiles); otherwise it
                       is the whole-dataset fitness in one call.
      metric:          (preds f32[P, D], y f32[D], spec) -> f32[P]
                       human-facing score (fraction correct, mean |err|,
                       R², ...) used by `GPSession.score`.

    Register EITHER `partial_fitness` (decomposable objectives; the
    moment pass is derived) OR `moments` + `reduce_moments` +
    `n_moments` (two-pass objectives; `partial_fitness` is derived) —
    `register_kernel` normalizes whichever is given. Supplying BOTH is
    also legal and lets a two-pass kernel keep a numerically superior
    whole-dataset formula (e.g. mean-centered pearson) for the un-tiled
    paths while the moment form serves tiling and meshes. A kernel
    registered with `decomposable=False` and no moment pass is legal but
    runs single-device only (no mesh, no data tiling).
    """

    name: str
    partial_fitness: Callable = None  # see class docstring
    metric: Callable = None  # (preds[P,D], y[D], spec) -> f32[P] human-facing
    aliases: tuple = ()
    decomposable: bool = True  # partial_fitness may be summed across data tiles
    moments: Callable = None  # phase 1: (preds, y, w, spec) -> f32[P, M]
    reduce_moments: Callable = None  # phase 2: (f32[..., M], spec) -> f32[...]
    n_moments: int = 1  # M — static so kernel output shapes are static
    combine_moments: Callable = None  # pairwise merge; None = elementwise sum
    y_moments: Callable = None  # (y, w, spec) -> f32[My] tree-independent cols
    y_moment_idx: tuple = ()  # positions of those columns in the M vector

    def merge_moments(self, m1, m2, spec):
        """Merge two moment partials — the ONE way any path (scan tile,
        Pallas grid, mesh shard fold) accumulates phase-1 output."""
        if self.combine_moments is None:
            return m1 + m2
        return self.combine_moments(m1, m2, spec)

    @property
    def tree_moment_idx(self) -> tuple:
        """Complement of `y_moment_idx`: the per-tree moment columns."""
        return tuple(i for i in range(self.n_moments)
                     if i not in self.y_moment_idx)


_REGISTRY: dict[str, FitnessKernel] = {}


def _normalize(kernel: FitnessKernel) -> FitnessKernel:
    """Fill in the derivable half of the two-pass protocol.

    partial_fitness only (decomposable)  -> derive moments/reduce_moments
    moments + reduce_moments             -> derive partial_fitness
    partial_fitness, decomposable=False  -> legacy single-device kernel
                                            (no moment pass; mesh paths
                                            reject it with a clear error)
    """
    if bool(kernel.y_moment_idx) != (kernel.y_moments is not None):
        raise ValueError(f"fitness kernel {kernel.name!r} must define "
                         f"y_moments and y_moment_idx together")
    if kernel.y_moment_idx and not all(
            0 <= i < kernel.n_moments for i in kernel.y_moment_idx):
        raise ValueError(f"fitness kernel {kernel.name!r} y_moment_idx "
                         f"{kernel.y_moment_idx} out of range for "
                         f"n_moments={kernel.n_moments}")
    if kernel.moments is not None:
        if kernel.reduce_moments is None:
            raise ValueError(f"fitness kernel {kernel.name!r} defines moments "
                             f"but no reduce_moments")
        mom, red = kernel.moments, kernel.reduce_moments
        repl = {}
        if kernel.partial_fitness is None:
            repl["partial_fitness"] = lambda p, y, w, s: red(mom(p, y, w, s), s)
        if kernel.n_moments > 1:
            # a multi-moment kernel's derived partial is the FULL fitness,
            # which is not summable across tiles
            repl["decomposable"] = False
        return dataclasses.replace(kernel, **repl) if repl else kernel
    if kernel.partial_fitness is None:
        raise ValueError(f"fitness kernel {kernel.name!r} must define either "
                         f"partial_fitness or moments + reduce_moments")
    if not kernel.decomposable:
        return kernel  # legacy full-data objective: single-device only
    pf = kernel.partial_fitness
    return dataclasses.replace(
        kernel,
        moments=lambda p, y, w, s: pf(p, y, w, s)[..., None],
        reduce_moments=lambda m, s: m[..., 0],
        n_moments=1)


def register_kernel(kernel: FitnessKernel, *, overwrite: bool = False) -> FitnessKernel:
    keys = (kernel.name, *kernel.aliases)
    if not overwrite:
        for key in keys:
            if key in _REGISTRY:
                raise ValueError(f"fitness kernel {key!r} already registered "
                                 f"(pass overwrite=True to replace)")
    kernel = _normalize(kernel)
    for key in keys:
        _REGISTRY[key] = kernel
    return kernel


def get_kernel(name: str) -> FitnessKernel:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown fitness kernel {name!r}; registered: "
                         f"{available_kernels()}") from None


def available_kernels() -> list[str]:
    return sorted({k.name for k in _REGISTRY.values()})


# --- built-in kernels ---------------------------------------------------------


def classify_labels(preds, n_classes: int):
    """Karoo's classification binning: round the regression output into
    {0..n_classes-1} with saturating ends."""
    return jnp.clip(jnp.round(preds), 0, n_classes - 1).astype(jnp.int32)


def _has_invalid(preds, w):
    """True per tree iff any valid data point evaluated to NaN."""
    return (jnp.isnan(preds) & (w[None, :] > 0)).any(-1)


def _nonfinite_count(preds, w):
    """f32[P] count of non-finite (NaN or ±inf) predictions at valid
    points — the summable invalid moment of the correlation kernels
    (count > 0 after the cross-tile sum iff any tile saw one). Unlike
    r/c/m/mse — where an inf prediction just loses points — an inf
    entering pearson/r2's products would poison the moments into NaN,
    and a NaN fitness WINS argmin; so these kernels declare the whole
    tree invalid (+inf fitness) instead."""
    return ((~jnp.isfinite(preds)) & (w > 0)).sum(-1).astype(jnp.float32)


def _regression_partial(preds, y, w, spec):
    err = jnp.abs(preds - y[None, :])
    err = jnp.where(w[None, :] > 0, err, 0.0)  # mask BEFORE inf-sanitize
    # inf-inf in an evolved expression yields NaN; a NaN fitness must
    # never win a tournament -> sanitize to +inf (minimize convention)
    return jnp.where(jnp.isnan(err), jnp.inf, err).sum(-1)


def _classify_partial(preds, y, w, spec):
    lab = jnp.clip(jnp.round(jnp.nan_to_num(preds)), 0, spec.n_classes - 1)
    hits = ((lab == y[None, :]) * w[None, :]).sum(-1)
    return jnp.where(_has_invalid(preds, w), jnp.inf, -hits)


def _match_partial(preds, y, w, spec):
    hit = jnp.abs(preds - y[None, :]) <= spec.precision  # NaN compares False
    hits = (hit * w[None, :]).sum(-1)
    return jnp.where(_has_invalid(preds, w), jnp.inf, -hits)


def _mse_partial(preds, y, w, spec):
    err2 = jnp.square(preds - y[None, :])
    err2 = jnp.where(w[None, :] > 0, err2, 0.0)
    return jnp.where(jnp.isnan(err2), jnp.inf, err2).sum(-1)


# Pearson (1 - r² against the target) needs global moments, so it is the
# canonical two-pass kernel. Phase 1 collects SHARD-LOCALLY CENTERED
# moments — count, means, centered second moments (M2) and co-moment —
# and `combine_moments` merges partials with Chan's parallel-variance
# formulas, so no path ever forms the raw E[x²]−E[x]² difference that
# cancels catastrophically in f32 when |mean| ≫ std (unnormalized
# targets). `xw = x * w` is computed FIRST wherever a prediction enters
# a product so zero-weight points contribute exact 0.0 even when the
# prediction saturated to ±3.4e38 (w * x² would overflow to inf·0 = NaN).
#
# pearson and r2 ALSO register an explicit `partial_fitness`: the
# mean-centered single-pass form, exact in f32, used whenever the whole
# dataset is in hand (fitness_from_preds, the un-tiled reference path,
# metric). The centered-moment form is within a few ulps of it on every
# tiled/sharded path — the old raw-moment caveat is gone.
#
# The y-only columns (count, ȳ, M2y) are computed ONCE per shard and
# broadcast across the population: `y_moment_idx` marks them so sharded
# reductions move them once instead of per tree (~half the reduction
# bytes for pearson), and the moment pass itself skips the per-tree
# recomputation (~1/num_nodes of eval FLOPs).

_PEARSON_MOMENTS = 7  # n=Σw, x̄, ȳ, M2x, M2y, Cxy, invalid-count
_PEARSON_Y_IDX = (0, 2, 4)  # n, ȳ, M2y — tree-independent


def _pearson_partial(preds, y, w, spec):
    """Exact centered single-pass 1 - r² (whole dataset in one call)."""
    w_ = w[None, :]
    n = _mean_divisor(w.sum())
    p0 = jnp.where(jnp.isfinite(preds), preds, 0.0)
    mx = (p0 * w_).sum(-1, keepdims=True) / n
    my = (y[None, :] * w_).sum(-1, keepdims=True) / n
    dx = (p0 - mx) * w_
    dy = (y[None, :] - my) * w_
    r2 = jnp.square((dx * dy).sum(-1)) / jnp.maximum(
        (dx * dx).sum(-1) * (dy * dy).sum(-1), 1e-12)
    invalid = ((~jnp.isfinite(preds)) & (w_ > 0)).any(-1)
    out = jnp.where(invalid, jnp.inf, 1.0 - r2)
    # huge-but-finite preds can still overflow dx² to inf -> inf/inf NaN;
    # a NaN fitness must never win a tournament
    return jnp.where(jnp.isnan(out), jnp.inf, out)


def _mean_divisor(n):
    """Safe divisor for a weighted mean: n itself whenever there is ANY
    weight (fractional sample weights included — `maximum(n, 1)` would
    silently shrink the mean for 0 < Σw < 1), 1.0 only for the empty
    (all-padding) case where the numerator is an exact 0.0 anyway."""
    return jnp.where(n > 0, n, 1.0)


def _y_center_moments(y, w, spec):
    """f32[3] tree-independent centered target moments: [Σw, ȳ, M2y]."""
    n = w.sum()
    my = (y * w).sum() / _mean_divisor(n)
    dy = y - my
    m2y = (dy * w * dy).sum()
    return jnp.stack([n, my, m2y])


def _pearson_moments(preds, y, w, spec):
    nym = _y_center_moments(y, w, spec)
    n, my = nym[0], nym[1]
    nz = _mean_divisor(n)
    w_ = jnp.broadcast_to(w[None, :], preds.shape)
    x0 = jnp.where(jnp.isfinite(preds), preds, 0.0)
    mx = (x0 * w_).sum(-1) / nz  # [P]
    dx = x0 - mx[..., None]
    dxw = dx * w_  # weight-first: padded ±3.4e38 preds contribute exact 0
    m2x = (dxw * dx).sum(-1)
    cxy = (dxw * (y - my)[None, :]).sum(-1)
    P = preds.shape[:-1]
    return jnp.stack([
        jnp.broadcast_to(n, P), mx, jnp.broadcast_to(my, P),
        m2x, jnp.broadcast_to(nym[2], P), cxy,
        _nonfinite_count(preds, w_),
    ], axis=-1)


def _chan_merge(n1, mean1, m2_1, n2, mean2, m2_2):
    """Chan's parallel combine of (count, mean, centered M2) pairs.
    Zero-count partials are exact identities (δ·n2/n selects the other
    side's mean; the M2 cross term vanishes)."""
    n = n1 + n2
    nz = _mean_divisor(n)
    delta = mean2 - mean1
    mean = mean1 + delta * n2 / nz
    m2 = m2_1 + m2_2 + delta * delta * n1 * n2 / nz
    return n, mean, m2, delta, nz


def _pearson_combine(m1, m2, spec):
    n1, n2 = m1[..., 0], m2[..., 0]
    n, mx, m2x, dx, nz = _chan_merge(n1, m1[..., 1], m1[..., 3],
                                     n2, m2[..., 1], m2[..., 3])
    _, my, m2y, dy, _ = _chan_merge(n1, m1[..., 2], m1[..., 4],
                                    n2, m2[..., 2], m2[..., 4])
    cxy = m1[..., 5] + m2[..., 5] + dx * dy * n1 * n2 / nz
    return jnp.stack([n, mx, my, m2x, m2y, cxy, m1[..., 6] + m2[..., 6]],
                     axis=-1)


# Below this level a variance is indistinguishable from the f32 noise of
# the Chan merge itself: each pairwise combine subtracts two shard means
# (rounding ~eps·|mean| each), so spurious variance accumulates at the
# (eps·mean)² scale. cov²/noise would then crown CONSTANT-prediction
# trees — which every GP population contains — as perfect (r²=1,
# fitness 0); treat anything below (256·eps·|mean|)² as zero correlation
# instead. 256 ulps leaves ~4 orders of magnitude of margin over the
# single-merge noise on each side; the resolution limit it implies is
# std/|mean| ≳ 3e-5 — ~8x finer than the old raw-moment form's
# cancellation point, and irrelevant for standardized targets.
_VAR_NOISE_FLOOR = 256 * 1.1920929e-07  # 256 * f32 machine epsilon


def _pearson_reduce(m, spec):
    n = _mean_divisor(m[..., 0])
    mx, my = m[..., 1], m[..., 2]
    # centered M2 never cancels, but clamp defensively at 0
    var_x = jnp.maximum(m[..., 3], 0.0) / n
    var_y = jnp.maximum(m[..., 4], 0.0) / n
    cov = m[..., 5] / n
    ok = ((var_x > jnp.square(_VAR_NOISE_FLOOR * mx))
          & (var_y > jnp.square(_VAR_NOISE_FLOOR * my))
          & (var_x > 0.0) & (var_y > 0.0))
    r2 = jnp.where(ok, jnp.clip(jnp.square(cov)
                                / jnp.maximum(var_x * var_y, 1e-12), 0.0, 1.0), 0.0)
    out = jnp.where(m[..., 6] > 0, jnp.inf, 1.0 - r2)
    return jnp.where(jnp.isnan(out), jnp.inf, out)  # NaN must never win


# Coefficient-of-determination kernel: fitness = 1 - R² = SSres/SStot
# (minimize; 0 = perfect fit). SSres is directly summable; SStot needs the
# global target mean — carried as centered (n, ȳ, M2y) with the Chan
# combine, like pearson. Registered purely through the two-pass protocol
# to prove the extension point (docs/fitness-kernels.md walks through it).

_R2_MOMENTS = 5  # n=Σw, ȳ, M2y, Σw(pred-y)², invalid-count
_R2_Y_IDX = (0, 1, 2)  # n, ȳ, M2y — tree-independent


def _r2_partial(preds, y, w, spec):
    """Exact centered single-pass 1 - R² (whole dataset in one call)."""
    w_ = w[None, :]
    n = _mean_divisor(w.sum())
    p0 = jnp.where(jnp.isfinite(preds), preds, 0.0)
    my = (y[None, :] * w_).sum(-1, keepdims=True) / n
    ss_tot = jnp.maximum((jnp.square(y[None, :] - my) * w_).sum(-1), 1e-12)
    ss_res = (jnp.square(p0 - y[None, :]) * w_).sum(-1)
    invalid = ((~jnp.isfinite(preds)) & (w_ > 0)).any(-1)
    out = jnp.where(invalid, jnp.inf, ss_res / ss_tot)
    return jnp.where(jnp.isnan(out), jnp.inf, out)


def _r2_moments(preds, y, w, spec):
    nym = _y_center_moments(y, w, spec)
    w_ = jnp.broadcast_to(w[None, :], preds.shape)
    yb = jnp.broadcast_to(y[None, :], preds.shape)
    x0 = jnp.where(jnp.isfinite(preds), preds, 0.0)
    err = (x0 - yb) * w_  # weight BEFORE squaring (see pearson note)
    P = preds.shape[:-1]
    return jnp.stack([
        jnp.broadcast_to(nym[0], P), jnp.broadcast_to(nym[1], P),
        jnp.broadcast_to(nym[2], P), (err * (x0 - yb)).sum(-1),
        _nonfinite_count(preds, w_),
    ], axis=-1)


def _r2_combine(m1, m2, spec):
    n, my, m2y, _, _ = _chan_merge(m1[..., 0], m1[..., 1], m1[..., 2],
                                   m2[..., 0], m2[..., 1], m2[..., 2])
    return jnp.stack([n, my, m2y, m1[..., 3] + m2[..., 3],
                      m1[..., 4] + m2[..., 4]], axis=-1)


def _r2_reduce(m, spec):
    ss_tot = jnp.maximum(m[..., 2], 1e-12)
    out = jnp.where(m[..., 4] > 0, jnp.inf, m[..., 3] / ss_tot)
    return jnp.where(jnp.isnan(out), jnp.inf, out)  # NaN must never win


register_kernel(FitnessKernel(
    name=REGRESSION, aliases=("regression", "abs"),
    partial_fitness=_regression_partial,
    metric=lambda preds, y, spec: jnp.abs(preds - y[None, :]).mean(-1)))
register_kernel(FitnessKernel(
    name=CLASSIFY, aliases=("classify", "classification"),
    partial_fitness=_classify_partial,
    metric=lambda preds, y, spec: (
        classify_labels(jnp.nan_to_num(preds), spec.n_classes)
        == y[None, :].astype(jnp.int32)).mean(-1)))
register_kernel(FitnessKernel(
    name=MATCH, aliases=("match",),
    partial_fitness=_match_partial,
    metric=lambda preds, y, spec: (
        jnp.abs(preds - y[None, :]) <= spec.precision).mean(-1)))
register_kernel(FitnessKernel(
    name="mse", partial_fitness=_mse_partial,
    metric=lambda preds, y, spec: jnp.square(preds - y[None, :]).mean(-1)))
register_kernel(FitnessKernel(
    name="pearson", n_moments=_PEARSON_MOMENTS,
    partial_fitness=_pearson_partial,
    moments=_pearson_moments, reduce_moments=_pearson_reduce,
    combine_moments=_pearson_combine,
    y_moments=_y_center_moments, y_moment_idx=_PEARSON_Y_IDX,
    metric=lambda preds, y, spec: _pearson_partial(
        preds, y, jnp.ones_like(y, jnp.float32), spec)))
register_kernel(FitnessKernel(
    name="r2", aliases=("r-squared",), n_moments=_R2_MOMENTS,
    partial_fitness=_r2_partial,
    moments=_r2_moments, reduce_moments=_r2_reduce,
    combine_moments=_r2_combine,
    y_moments=_y_center_moments, y_moment_idx=_R2_Y_IDX,
    metric=lambda preds, y, spec: 1.0 - _r2_partial(
        preds, y, jnp.ones_like(y, jnp.float32), spec)))


# --- convenience entry points (kept for callers that hold raw preds) ---------


def fitness_from_preds(preds, y, spec: FitnessSpec, weight=None):
    """preds: [P, D] predictions; y: [D] targets. Returns float32[P]
    (minimize) — the whole-dataset fitness in one call (both phases for
    two-pass kernels)."""
    y = y.astype(jnp.float32)
    w = jnp.ones_like(y) if weight is None else weight.astype(jnp.float32)
    return get_kernel(spec.kernel).partial_fitness(preds, y, w, spec)


def moments_from_preds(preds, y, spec: FitnessSpec, weight=None):
    """Phase 1 only: f32[P, M] weighted moment partials of preds[P, D]
    against y[D]. Merge the [P, M] partials from every tile/shard with
    `kern.merge_moments` (elementwise sum unless the kernel defines a
    `combine_moments`), then finish with `kern.reduce_moments`."""
    kern = get_kernel(spec.kernel)
    if kern.moments is None:
        raise ValueError(f"fitness kernel {kern.name!r} defines no moment pass; "
                         f"it cannot be tiled or sharded over data")
    y = y.astype(jnp.float32)
    w = jnp.ones_like(y) if weight is None else weight.astype(jnp.float32)
    return kern.moments(preds, y, w, spec)


def fold_moment_partials(kern: FitnessKernel, parts, spec: FitnessSpec):
    """Merge a sequence of f32[..., M] moment partials (one per
    tile/shard) into one, via the kernel's associative merge."""
    total = parts[0]
    for p in parts[1:]:
        total = kern.merge_moments(total, p, spec)
    return total


def scatter_tree_y(kern: FitnessKernel, tree_m, y_m):
    """Reassemble a full f32[..., M] moment vector from the per-tree
    columns `tree_m` f32[..., Mt] and the hoisted tree-independent
    columns `y_m` f32[My] (broadcast across the leading axes) — the
    inverse of slicing by `tree_moment_idx` / `y_moment_idx`."""
    shape = (*tree_m.shape[:-1], kern.n_moments)
    out = jnp.zeros(shape, tree_m.dtype)
    out = out.at[..., jnp.asarray(kern.tree_moment_idx)].set(tree_m)
    return out.at[..., jnp.asarray(kern.y_moment_idx)].set(
        jnp.broadcast_to(y_m, (*tree_m.shape[:-1], len(kern.y_moment_idx))))


def accuracy_from_preds(preds, y, spec: FitnessSpec):
    """Human-facing metric (fraction correct / mean abs err) for reporting."""
    return get_kernel(spec.kernel).metric(preds, y.astype(jnp.float32), spec)
