"""Fitness kernels — a registry of pluggable GP objectives.

Karoo GP appends a per-kernel fitness sub-graph to each tree's TF graph;
we fuse the same reductions after the vectorized evaluation. The paper's
three kernels — (r)egression, (c)lassification, (m)atch — ship built in,
plus `mse` and `pearson`; new objectives register a `FitnessKernel` and
every evaluation path (jnp reference, tiled reference, Pallas fused
kernel, scalar baseline) and the selection code pick them up without
modification.

Conventions every kernel obeys:

  * MINIMIZE — lower fitness is better (classify and match are negated
    hit counts), so selection code is kernel-agnostic.
  * `partial_fitness(preds, y, weight, spec)` returns a per-tree f32[P]
    partial over one data tile. When `decomposable`, partials from
    different tiles are summed (jnp tiling, Pallas grid accumulation,
    mesh `psum`) to form the full fitness; non-decomposable kernels
    (e.g. Pearson) only run on un-tiled single-device paths.
  * `weight` masks data padding: points with weight 0 contribute nothing.
  * NaN sanitization — a NaN prediction at any *valid* (weight > 0)
    point makes the tree's fitness +inf. A NaN-producing tree must never
    win a tournament in ANY kernel (`round(NaN)` → int is undefined, so
    classify/match cannot just bin the prediction).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

REGRESSION = "r"
CLASSIFY = "c"
MATCH = "m"


@dataclasses.dataclass(frozen=True)
class FitnessSpec:
    kernel: str = REGRESSION  # any name registered in the kernel registry
    n_classes: int = 3  # classify only
    precision: float = 1e-4  # match tolerance (paper: 4 decimal places)

    def __hash__(self):
        return hash((self.kernel, self.n_classes, self.precision))


@dataclasses.dataclass(frozen=True)
class FitnessKernel:
    """One pluggable objective. `partial_fitness` and `metric` must be
    pure jnp (they also run inside the Pallas kernel body and under
    shard_map)."""

    name: str
    partial_fitness: Callable  # (preds[P,D], y[D], w[D], spec) -> f32[P]
    metric: Callable  # (preds[P,D], y[D], spec) -> f32[P] human-facing
    aliases: tuple = ()
    decomposable: bool = True  # partials may be summed across data tiles


_REGISTRY: dict[str, FitnessKernel] = {}


def register_kernel(kernel: FitnessKernel, *, overwrite: bool = False) -> FitnessKernel:
    for key in (kernel.name, *kernel.aliases):
        if key in _REGISTRY and not overwrite:
            raise ValueError(f"fitness kernel {key!r} already registered "
                             f"(pass overwrite=True to replace)")
        _REGISTRY[key] = kernel
    return kernel


def get_kernel(name: str) -> FitnessKernel:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown fitness kernel {name!r}; registered: "
                         f"{available_kernels()}") from None


def available_kernels() -> list[str]:
    return sorted({k.name for k in _REGISTRY.values()})


# --- built-in kernels ---------------------------------------------------------


def classify_labels(preds, n_classes: int):
    """Karoo's classification binning: round the regression output into
    {0..n_classes-1} with saturating ends."""
    return jnp.clip(jnp.round(preds), 0, n_classes - 1).astype(jnp.int32)


def _has_invalid(preds, w):
    """True per tree iff any valid data point evaluated to NaN."""
    return (jnp.isnan(preds) & (w[None, :] > 0)).any(-1)


def _regression_partial(preds, y, w, spec):
    err = jnp.abs(preds - y[None, :])
    err = jnp.where(w[None, :] > 0, err, 0.0)  # mask BEFORE inf-sanitize
    # inf-inf in an evolved expression yields NaN; a NaN fitness must
    # never win a tournament -> sanitize to +inf (minimize convention)
    return jnp.where(jnp.isnan(err), jnp.inf, err).sum(-1)


def _classify_partial(preds, y, w, spec):
    lab = jnp.clip(jnp.round(jnp.nan_to_num(preds)), 0, spec.n_classes - 1)
    hits = ((lab == y[None, :]) * w[None, :]).sum(-1)
    return jnp.where(_has_invalid(preds, w), jnp.inf, -hits)


def _match_partial(preds, y, w, spec):
    hit = jnp.abs(preds - y[None, :]) <= spec.precision  # NaN compares False
    hits = (hit * w[None, :]).sum(-1)
    return jnp.where(_has_invalid(preds, w), jnp.inf, -hits)


def _mse_partial(preds, y, w, spec):
    err2 = jnp.square(preds - y[None, :])
    err2 = jnp.where(w[None, :] > 0, err2, 0.0)
    return jnp.where(jnp.isnan(err2), jnp.inf, err2).sum(-1)


def _pearson_partial(preds, y, w, spec):
    """1 - r² against the target — needs global moments, so this kernel is
    NOT decomposable over data tiles."""
    w_ = w[None, :]
    n = jnp.maximum(w.sum(), 1.0)
    p0 = jnp.nan_to_num(preds)
    mx = (p0 * w_).sum(-1, keepdims=True) / n
    my = (y[None, :] * w_).sum(-1, keepdims=True) / n
    dx = (p0 - mx) * w_
    dy = (y[None, :] - my) * w_
    r2 = jnp.square((dx * dy).sum(-1)) / jnp.maximum(
        (dx * dx).sum(-1) * (dy * dy).sum(-1), 1e-12)
    return jnp.where(_has_invalid(preds, w), jnp.inf, 1.0 - r2)


register_kernel(FitnessKernel(
    name=REGRESSION, aliases=("regression", "abs"),
    partial_fitness=_regression_partial,
    metric=lambda preds, y, spec: jnp.abs(preds - y[None, :]).mean(-1)))
register_kernel(FitnessKernel(
    name=CLASSIFY, aliases=("classify", "classification"),
    partial_fitness=_classify_partial,
    metric=lambda preds, y, spec: (
        classify_labels(jnp.nan_to_num(preds), spec.n_classes)
        == y[None, :].astype(jnp.int32)).mean(-1)))
register_kernel(FitnessKernel(
    name=MATCH, aliases=("match",),
    partial_fitness=_match_partial,
    metric=lambda preds, y, spec: (
        jnp.abs(preds - y[None, :]) <= spec.precision).mean(-1)))
register_kernel(FitnessKernel(
    name="mse", partial_fitness=_mse_partial,
    metric=lambda preds, y, spec: jnp.square(preds - y[None, :]).mean(-1)))
register_kernel(FitnessKernel(
    name="pearson", decomposable=False,
    partial_fitness=_pearson_partial,
    metric=lambda preds, y, spec: _pearson_partial(
        preds, y, jnp.ones_like(y, jnp.float32), spec)))


# --- convenience entry points (kept for callers that hold raw preds) ---------


def fitness_from_preds(preds, y, spec: FitnessSpec, weight=None):
    """preds: [P, D] predictions; y: [D] targets. Returns float32[P] (minimize)."""
    y = y.astype(jnp.float32)
    w = jnp.ones_like(y) if weight is None else weight.astype(jnp.float32)
    return get_kernel(spec.kernel).partial_fitness(preds, y, w, spec)


def accuracy_from_preds(preds, y, spec: FitnessSpec):
    """Human-facing metric (fraction correct / mean abs err) for reporting."""
    return get_kernel(spec.kernel).metric(preds, y.astype(jnp.float32), spec)
