"""Fitness kernels — Karoo GP's (r)egression, (c)lassification, (m)atch.

Karoo appends a per-kernel fitness sub-graph to each tree's TF graph; we
fuse the same reductions after the vectorized evaluation. All kernels
return a per-tree score under a common MINIMIZE convention (classify and
match are negated hit-counts) so selection code is kernel-agnostic.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

REGRESSION = "r"
CLASSIFY = "c"
MATCH = "m"


@dataclasses.dataclass(frozen=True)
class FitnessSpec:
    kernel: str = REGRESSION  # 'r' | 'c' | 'm'
    n_classes: int = 3  # classify only
    precision: float = 1e-4  # match tolerance (paper: 4 decimal places)

    def __hash__(self):
        return hash((self.kernel, self.n_classes, self.precision))


def classify_labels(preds, n_classes: int):
    """Karoo's classification binning: round the regression output into
    {0..n_classes-1} with saturating ends."""
    return jnp.clip(jnp.round(preds), 0, n_classes - 1).astype(jnp.int32)


def fitness_from_preds(preds, y, spec: FitnessSpec):
    """preds: [P, D] predictions; y: [D] targets. Returns float32[P] (minimize)."""
    y = y.astype(jnp.float32)
    if spec.kernel == REGRESSION:
        err = jnp.abs(preds - y[None, :])
        # inf-inf in an evolved expression yields NaN; a NaN fitness must
        # never win a tournament -> sanitize to +inf (minimize convention)
        return jnp.where(jnp.isnan(err), jnp.inf, err).sum(-1)
    if spec.kernel == CLASSIFY:
        hits = (classify_labels(preds, spec.n_classes) == y[None, :].astype(jnp.int32)).sum(-1)
        return -hits.astype(jnp.float32)
    if spec.kernel == MATCH:
        hits = (jnp.abs(preds - y[None, :]) <= spec.precision).sum(-1)
        return -hits.astype(jnp.float32)
    raise ValueError(f"unknown fitness kernel {spec.kernel!r}")


def accuracy_from_preds(preds, y, spec: FitnessSpec):
    """Human-facing metric (fraction correct / mean abs err) for reporting."""
    if spec.kernel == CLASSIFY:
        return (classify_labels(preds, spec.n_classes) == y[None, :].astype(jnp.int32)).mean(-1)
    if spec.kernel == MATCH:
        return (jnp.abs(preds - y[None, :]) <= spec.precision).mean(-1)
    return jnp.abs(preds - y[None, :]).mean(-1)
