"""Island-model evolution: first-class population layout on any topology.

The paper never leaves a single system board (§3.4: "Karoo was not tested
across a tightly coupled parallel cluster"). The classic GP island model
is how real deployments use many cores/devices: independent
sub-populations with decorrelated RNG, cross-pollinated by periodic
elite migration. Here islands are a *population layout*, not a device
requirement: an evolution run is `I` islands of `P` trees
(`op: int32[I, P, N]`) that

  * runs entirely on ONE device (the island axis is vmapped through the
    generation step, migration is a `jnp.roll`/gather over the leading
    axis),
  * or shards the island axis over the mesh `pod` axis (migration
    lowers to `lax.ppermute`, the multi-device story),
  * or BOTH at once — pods × in-device islands, where the two lowerings
    compose: in-device routing moves elites between a pod's local
    islands and the pod-boundary islands exchange via `ppermute`.

`IslandConfig` also carries the *heterogeneous search* knobs: per-island
operator mixes, tournament sizes and point-mutation rates become arrays
vmapped through `evolve.next_generation_arrays`, so one compiled program
runs I different search regimes and migration cross-pollinates them.

Migration volume is O(I · k · nodes) bytes — negligible against
evaluation — and overlaps with the generation step under XLA's scheduler.

Topologies (`IslandConfig.topology`):

  ring            island i's elites replace the last-k offspring slots
                  of island (i+1) mod I (global ring over pods × local
                  islands, pod-major order)
  torus           islands arranged on a 2D grid (pods × local islands on
                  a mesh, else the squarest factorization of I);
                  migration events alternate east / south shifts
  broadcast-best  the island holding the generation's best tree sends
                  its elites to every island
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core.evolve import OperatorMix

TOPOLOGIES = ("ring", "torus", "broadcast-best")


@dataclasses.dataclass(frozen=True)
class IslandConfig:
    """Island layout + migration policy + per-island search knobs.

    islands        number of islands I (1 = the classic single-population
                   layout; state keeps its legacy un-batched shapes)
    migrate_every  generations between migration events
    migrate_k      elites exchanged per event (replace the receiving
                   island's last k offspring slots)
    topology       "ring" | "torus" | "broadcast-best" (see module doc)
    mixes          optional per-island OperatorMix tuple (len == islands)
                   — heterogeneous operator regimes; None = GPConfig.mix
                   everywhere
    tourn_sizes    optional per-island tournament sizes (len == islands);
                   None = GPConfig.tourn_size everywhere
    point_rates    optional per-island point-mutation redraw
                   probabilities (len == islands); None = the 0.25
                   default everywhere
    """

    islands: int = 1
    migrate_every: int = 10
    migrate_k: int = 4
    topology: str = "ring"
    mixes: tuple = None
    tourn_sizes: tuple = None
    point_rates: tuple = None

    def __post_init__(self):
        if self.topology not in TOPOLOGIES:
            raise ValueError(f"unknown island topology {self.topology!r}; "
                             f"one of {TOPOLOGIES}")
        if self.islands < 1:
            raise ValueError(f"islands must be >= 1, got {self.islands}")
        if self.migrate_every < 1:
            # generation % 0 inside jit is silent platform-defined garbage
            raise ValueError(f"migrate_every must be >= 1, got "
                             f"{self.migrate_every}")
        if self.migrate_k < 0:
            raise ValueError(f"migrate_k must be >= 0, got {self.migrate_k}")
        for name in ("mixes", "tourn_sizes", "point_rates"):
            val = getattr(self, name)
            if val is not None:
                object.__setattr__(self, name, tuple(val))
                if len(getattr(self, name)) != self.islands:
                    raise ValueError(f"IslandConfig.{name} has "
                                     f"{len(getattr(self, name))} entries for "
                                     f"{self.islands} islands")

    def __hash__(self):
        return hash((self.islands, self.migrate_every, self.migrate_k,
                     self.topology, self.mixes, self.tourn_sizes,
                     self.point_rates))

    # --- heterogeneous-search parameter arrays (host-side, static) ----------

    def prob_table(self, default_mix: OperatorMix) -> np.ndarray:
        """f32[I, 4] operator-mix probabilities per island."""
        mixes = self.mixes or (default_mix,) * self.islands
        return np.stack([m.probs() for m in mixes])

    def tourn_table(self, default_size: int) -> tuple[int, np.ndarray]:
        """(static max draw size, int32[I] per-island active sizes)."""
        sizes = self.tourn_sizes or (default_size,) * self.islands
        return int(max(sizes)), np.asarray(sizes, np.int32)

    def point_rate_table(self) -> np.ndarray:
        """f32[I] per-island point-mutation redraw probabilities."""
        rates = self.point_rates or (0.25,) * self.islands
        return np.asarray(rates, np.float32)


def torus_grid(islands: int) -> tuple[int, int]:
    """The squarest (rows, cols) factorization of `islands` — the island
    grid the single-device torus topology routes on. Prime counts
    degenerate to (1, I): a ring."""
    r = 1
    for d in range(int(np.sqrt(islands)), 0, -1):
        if islands % d == 0:
            r = d
            break
    return r, islands // r


def take_island(state, idx):
    """Island `idx`'s slice of an island-batched state pytree: leaves with
    a leading island axis lose it ([I, ...] -> [...]), scalar leaves (the
    shared generation counter) pass through unchanged. The inverse of
    `splice_island` — together they are the slot-level state swap the
    multi-tenant service uses to move one job's evolution state in and
    out of a batch."""
    return jax.tree.map(lambda a: a[idx] if jnp.ndim(a) else a, state)


def splice_island(state, idx, sub):
    """Replace island slot `idx` of an island-batched state pytree with
    `sub` (one island's un-batched leaves, as produced by `take_island`
    or a fresh per-job init). Leaves whose rank matches the batched
    leaf's (shared scalars) keep the batched value. Host-eager `.at[]`
    updates — call between block dispatches, not inside jit."""
    def put(a, v):
        if jnp.ndim(a) == jnp.ndim(v):
            return a  # shared leaf (e.g. the lockstep generation scalar)
        return a.at[idx].set(v)

    return jax.tree.map(put, state, sub)


def island_elites(op, arg, fitness, k: int):
    """Per-island top-k trees of the just-evaluated population.

    op/arg: int32[I, P, N], fitness: f32[I, P] → int32[I, k, N] pairs,
    best-first."""
    order = jnp.argsort(fitness, axis=-1)[:, :k]  # [I, k]
    return (jnp.take_along_axis(op, order[:, :, None], axis=1),
            jnp.take_along_axis(arg, order[:, :, None], axis=1))


def _route_local(icfg: IslandConfig, elite_op, elite_arg, event_idx, fit_best):
    """In-device routing: [I, k, N] elites → the [I, k, N] arrivals each
    island receives, per `icfg.topology`. `event_idx` (traced int32) is
    the migration-event counter (torus alternates direction on its
    parity); `fit_best` (f32[I]) picks broadcast-best's champion."""
    I = elite_op.shape[0]
    if icfg.topology == "ring":
        return jnp.roll(elite_op, 1, axis=0), jnp.roll(elite_arg, 1, axis=0)
    if icfg.topology == "torus":
        r, c = torus_grid(I)

        def shift(x):
            g = x.reshape(r, c, *x.shape[1:])
            east = jnp.roll(g, 1, axis=1).reshape(x.shape)
            south = jnp.roll(g, 1, axis=0).reshape(x.shape)
            return jnp.where(event_idx % 2 == 0, east, south)

        return shift(elite_op), shift(elite_arg)
    # broadcast-best: every island receives the champion island's elites
    champ = jnp.argmin(fit_best)
    return (jnp.broadcast_to(elite_op[champ], elite_op.shape),
            jnp.broadcast_to(elite_arg[champ], elite_arg.shape))


def migrate_local(icfg: IslandConfig, new_op, new_arg, elite_op, elite_arg,
                  generation, fit_best):
    """In-device lowering of island migration.

    new_op/new_arg: int32[I, P, N] — the bred next generation.
    elite_op/elite_arg: int32[I, k, N] — each island's best k trees from
    the just-evaluated population (`island_elites`). fit_best: f32[I] —
    each island's best fitness this generation (broadcast-best routing).
    When a migration generation comes due every island's last k offspring
    slots are overwritten by the routed arrivals; otherwise the
    generation passes through unchanged (a branch-free select, so the
    compiled program is identical every generation)."""
    k = icfg.migrate_k
    if k <= 0 or new_op.shape[0] <= 1:
        return new_op, new_arg
    event_idx = generation // icfg.migrate_every
    inc_op, inc_arg = _route_local(icfg, elite_op, elite_arg, event_idx, fit_best)
    due = (generation % icfg.migrate_every) == (icfg.migrate_every - 1)
    new_op = jnp.where(due, new_op.at[:, -k:].set(inc_op), new_op)
    new_arg = jnp.where(due, new_arg.at[:, -k:].set(inc_arg), new_arg)
    return new_op, new_arg


def migrate_sharded(icfg: IslandConfig, new_op, new_arg, elite_op, elite_arg,
                    generation, fit_best, pod_axis: str | None, is_receiver):
    """Mesh lowering: pods × in-device islands (called inside shard_map).

    Shapes are per-shard: new_op/new_arg int32[I_local, P_local, N] (this
    model-rank's slice of the pod's local islands), elite_op/elite_arg
    int32[I_local, k, N] and fit_best f32[I_local] replicated within the
    pod (gathered population), so every rank performs identical
    collectives. `is_receiver` gates the overwrite to the model rank
    whose slice holds each island's last k offspring slots.

    Composition with the in-device lowering, per topology:

      ring            global ring in pod-major order: local islands roll
                      in-device; local island 0 receives the PREVIOUS
                      pod's last island via `ppermute`
      torus           grid = (pods × local islands): east = in-device
                      roll, south = `ppermute` of all local elites to
                      the next pod; events alternate
      broadcast-best  champion selected across ALL pods × islands
                      (`all_gather` of per-pod champions), broadcast
                      everywhere
    """
    k = icfg.migrate_k
    I_local = new_op.shape[0]
    n_pods = compat.axis_size(pod_axis) if pod_axis else 1
    if k <= 0 or I_local * n_pods <= 1:
        return new_op, new_arg
    event_idx = generation // icfg.migrate_every
    perm = [(i, (i + 1) % n_pods) for i in range(n_pods)]

    if icfg.topology == "ring":
        inc_op = jnp.roll(elite_op, 1, axis=0)
        inc_arg = jnp.roll(elite_arg, 1, axis=0)
        if n_pods > 1:
            inc_op = inc_op.at[0].set(
                jax.lax.ppermute(elite_op[-1], pod_axis, perm))
            inc_arg = inc_arg.at[0].set(
                jax.lax.ppermute(elite_arg[-1], pod_axis, perm))
    elif icfg.topology == "torus":
        if n_pods > 1:
            east_op = jnp.roll(elite_op, 1, axis=0)
            east_arg = jnp.roll(elite_arg, 1, axis=0)
            if I_local == 1:
                # a 1-wide row degenerates east to the pod ring
                east_op = jax.lax.ppermute(elite_op, pod_axis, perm)
                east_arg = jax.lax.ppermute(elite_arg, pod_axis, perm)
            south_op = jax.lax.ppermute(elite_op, pod_axis, perm)
            south_arg = jax.lax.ppermute(elite_arg, pod_axis, perm)
            alt = event_idx % 2 == 0
            inc_op = jnp.where(alt, east_op, south_op)
            inc_arg = jnp.where(alt, east_arg, south_arg)
        else:
            inc_op, inc_arg = _route_local(icfg, elite_op, elite_arg,
                                           event_idx, fit_best)
    else:  # broadcast-best
        champ = jnp.argmin(fit_best)
        c_op, c_arg, c_fit = elite_op[champ], elite_arg[champ], fit_best[champ]
        if n_pods > 1:
            pods_fit = jax.lax.all_gather(c_fit, pod_axis)  # [n_pods]
            pods_op = jax.lax.all_gather(c_op, pod_axis)  # [n_pods, k, N]
            pods_arg = jax.lax.all_gather(c_arg, pod_axis)
            g = jnp.argmin(pods_fit)
            c_op, c_arg = pods_op[g], pods_arg[g]
        inc_op = jnp.broadcast_to(c_op, elite_op.shape)
        inc_arg = jnp.broadcast_to(c_arg, elite_arg.shape)

    due = ((generation % icfg.migrate_every) == (icfg.migrate_every - 1)) & is_receiver
    new_op = jnp.where(due, new_op.at[:, -k:].set(inc_op), new_op)
    new_arg = jnp.where(due, new_arg.at[:, -k:].set(inc_arg), new_arg)
    return new_op, new_arg


def migrate(cfg, op_local, arg_local, elite_op, elite_arg, generation,
            pod_axis: str, is_receiver):
    """Legacy pod-axis ring lowering (islands=1 runs with pop sharded over
    pods; called inside shard_map). Kept bit-for-bit: the pod slices ARE
    the islands, one per pod, and every `migrate_every` generations each
    pod's `migrate_k` best trees ride a ring `collective_permute` to the
    next pod, replacing offspring slots there.

    op_local/arg_local: int32[P_local, N] — this device's slice of the NEW
    generation. elite_op/elite_arg: int32[k, N] — this pod's best k trees
    from the just-evaluated population (replicated within the pod, so
    every model-rank performs an identical permute). The receiving rank
    (`is_receiver`, one per pod) overwrites its last k offspring slots
    when a migration generation comes due.
    """
    n_pods = compat.axis_size(pod_axis)
    if n_pods <= 1:
        return op_local, arg_local
    k = cfg.migrate_k
    perm = [(i, (i + 1) % n_pods) for i in range(n_pods)]
    mig_op = jax.lax.ppermute(elite_op, pod_axis, perm)
    mig_arg = jax.lax.ppermute(elite_arg, pod_axis, perm)

    due = ((generation % cfg.migrate_every) == (cfg.migrate_every - 1)) & is_receiver
    new_op = jnp.where(due, op_local.at[-k:].set(mig_op), op_local)
    new_arg = jnp.where(due, arg_local.at[-k:].set(mig_arg), arg_local)
    return new_op, new_arg
