"""Island-model evolution across the pod axis.

The paper never leaves a single system board (§3.4: "Karoo was not tested
across a tightly coupled parallel cluster"). To make the technique
runnable at pod scale we use the classic GP island model: each pod evolves
an independent sub-population (decorrelated RNG via fold_in(pod_index)),
and every `migrate_every` generations each pod's `migrate_k` best trees
ride a ring `collective_permute` to the next pod, replacing offspring
slots there. Migration volume is O(k · nodes) bytes — negligible against
evaluation — and overlaps with the generation step under XLA's scheduler.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat


def migrate(cfg, op_local, arg_local, elite_op, elite_arg, generation,
            pod_axis: str, is_receiver):
    """Ring-migrate pod elites (called inside shard_map).

    op_local/arg_local: int32[P_local, N] — this device's slice of the NEW
    generation. elite_op/elite_arg: int32[k, N] — this pod's best k trees
    from the just-evaluated population (replicated within the pod, so
    every model-rank performs an identical permute). The receiving rank
    (`is_receiver`, one per pod) overwrites its last k offspring slots
    when a migration generation comes due.
    """
    n_pods = compat.axis_size(pod_axis)
    if n_pods <= 1:
        return op_local, arg_local
    k = cfg.migrate_k
    perm = [(i, (i + 1) % n_pods) for i in range(n_pods)]
    mig_op = jax.lax.ppermute(elite_op, pod_axis, perm)
    mig_arg = jax.lax.ppermute(elite_arg, pod_axis, perm)

    due = ((generation % cfg.migrate_every) == (cfg.migrate_every - 1)) & is_receiver
    new_op = jnp.where(due, op_local.at[-k:].set(mig_op), op_local)
    new_arg = jnp.where(due, arg_local.at[-k:].set(mig_arg), arg_local)
    return new_op, new_arg
