"""Vectorized population evaluation — the paper's core technique, in JAX.

The paper transposes the dataset so each feature is a vector (its Eq. 1→2)
and evaluates each tree's expression as a TensorFlow graph over those
vectors. Here the *whole population* is evaluated by one level-synchronous
sweep over the heap encoding:

    for level d = max_depth .. 0:
        node_val[d] = select(opcode, f(child_vals[d+1]), terminal_vals)

Every step is a fused elementwise select over a [pop, 2**d, data] block —
one static XLA program for any population content. This module is the pure
jnp reference path; kernels/gp_eval.py is the Pallas TPU version of the
same contraction (fused with the fitness reduction), and kernels/ref.py
re-exports these functions as the kernel oracle.

Predictions are computed for EVERY data column, padded or not — dataset
padding (data/loader.pad_rows) is masked one layer up, where the
`weight: f32[D]` vector zeroes padded points out of the fitness
reduction (core/fitness partial_fitness, kernels/ref, kernels/ops, and
the Pallas kernel's w_ref all share that convention), so a padded
dataset scores exactly like the unpadded one.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import primitives as prim
from repro.core.trees import TreeSpec


@partial(jax.jit, static_argnames=("spec",))
def evaluate_population(op, arg, X, const_table, spec: TreeSpec):
    """Evaluate every tree against every data point.

    op, arg:     int32[P, N]        population in the spec's genome form
    X:           float[F, D]        feature-major data (the paper's Eq. 2 layout)
    const_table: float[C]
    returns      float32[P, D]      predictions

    Dispatches on spec.genome: heap populations run the level sweep
    below; postfix populations run the stack machine
    (`evaluate_population_postfix`). Both apply the same f32 primitives
    to the same operand values in the same order per node, so the two
    forms of one tree produce bitwise-identical predictions.
    """
    if spec.genome == "postfix":
        return evaluate_population_postfix(op, arg, X, const_table, spec)
    P, N = op.shape
    D = X.shape[1]
    max_depth = (N + 1).bit_length() - 2
    X = X.astype(jnp.float32)
    const_table = const_table.astype(jnp.float32)

    vals = None  # child-level buffer [P, 2**(d+1), D]
    for d in range(max_depth, -1, -1):
        lo, w = 2**d - 1, 2**d
        opd = op[:, lo:lo + w, None]  # [P, w, 1]
        argd = arg[:, lo:lo + w]
        feat = X[jnp.clip(argd, 0, X.shape[0] - 1)]  # [P, w, D] gather
        cons = const_table[jnp.clip(argd, 0, const_table.shape[0] - 1)][..., None]
        node = jnp.where(opd == prim.FEATURE, feat, jnp.broadcast_to(cons, (P, w, D)))
        if vals is not None:
            lhs, rhs = vals[:, 0::2], vals[:, 1::2]
            fn = prim.apply_function(opd, lhs, rhs, spec.fn_set)
            node = jnp.where(opd >= 3, fn, node)
        node = jnp.where(opd == prim.EMPTY, 0.0, node)
        vals = node
    return vals[:, 0]  # [P, D]


@partial(jax.jit, static_argnames=("spec",))
def evaluate_population_postfix(op, arg, X, const_table, spec: TreeSpec):
    """Stack-machine evaluation of postfix populations — the jnp
    reference for the Pallas stack kernel (kernels/gp_eval.py).

    One `lax.scan` over all NODES instruction slots carries an operand
    stack f32[P, stack_size, D] (slot 0 = top): terminals shift-push
    their value, unary functions replace the top, binary functions fold
    the top two and shift up; EMPTY slots hold the stack unchanged, so
    rows of different active lengths share the fixed-trip scan. Applies
    the identical f32 primitives (`prim.apply_function`) to the same
    operand values as the heap level sweep — bitwise-equal predictions
    for the two forms of one tree.
    """
    P, N = op.shape
    D = X.shape[1]
    S = spec.stack_size
    X = X.astype(jnp.float32)
    const_table = const_table.astype(jnp.float32)
    ARITY = jnp.asarray(prim.ARITY)

    def step(stack, xs):
        opt, argt = xs  # int32[P]
        feat = X[jnp.clip(argt, 0, X.shape[0] - 1)]  # [P, D]
        cons = const_table[jnp.clip(argt, 0, const_table.shape[0] - 1)][:, None]
        tval = jnp.where((opt == prim.FEATURE)[:, None], feat,
                         jnp.broadcast_to(cons, (P, D)))
        top = stack[:, 0]
        ar = ARITY[opt]
        lhs = jnp.where((ar == 2)[:, None], stack[:, 1], top)
        fnv = prim.apply_function(opt[:, None], lhs, top, spec.fn_set)
        push = jnp.concatenate([tval[:, None], stack[:, :S - 1]], axis=1)
        una = stack.at[:, 0].set(fnv)
        binr = jnp.concatenate(
            [fnv[:, None], stack[:, 2:], jnp.zeros((P, 1, D), jnp.float32)],
            axis=1)
        a = ar[:, None, None]
        new = jnp.where(a == 0, push, jnp.where(a == 1, una, binr))
        new = jnp.where((opt == prim.EMPTY)[:, None, None], stack, new)
        return new, None

    stack0 = jnp.zeros((P, S, D), jnp.float32)
    stack, _ = jax.lax.scan(step, stack0, (op.T, arg.T))
    return stack[:, 0]  # [P, D]; all-EMPTY rows stay 0.0 like the heap path


def evaluate_tree(op_row, arg_row, X, const_table, spec: TreeSpec):
    """Single-tree convenience wrapper (used by tests/examples)."""
    preds = evaluate_population(op_row[None], arg_row[None], X, const_table, spec)
    return preds[0]
