"""Vectorized population evaluation — the paper's core technique, in JAX.

The paper transposes the dataset so each feature is a vector (its Eq. 1→2)
and evaluates each tree's expression as a TensorFlow graph over those
vectors. Here the *whole population* is evaluated by one level-synchronous
sweep over the heap encoding:

    for level d = max_depth .. 0:
        node_val[d] = select(opcode, f(child_vals[d+1]), terminal_vals)

Every step is a fused elementwise select over a [pop, 2**d, data] block —
one static XLA program for any population content. This module is the pure
jnp reference path; kernels/gp_eval.py is the Pallas TPU version of the
same contraction (fused with the fitness reduction), and kernels/ref.py
re-exports these functions as the kernel oracle.

Predictions are computed for EVERY data column, padded or not — dataset
padding (data/loader.pad_rows) is masked one layer up, where the
`weight: f32[D]` vector zeroes padded points out of the fitness
reduction (core/fitness partial_fitness, kernels/ref, kernels/ops, and
the Pallas kernel's w_ref all share that convention), so a padded
dataset scores exactly like the unpadded one.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from typing import NamedTuple

from repro.core import primitives as prim
from repro.core import trees as trees_mod
from repro.core.trees import TreeSpec


@partial(jax.jit, static_argnames=("spec",))
def evaluate_population(op, arg, X, const_table, spec: TreeSpec):
    """Evaluate every tree against every data point.

    op, arg:     int32[P, N]        population in the spec's genome form
    X:           float[F, D]        feature-major data (the paper's Eq. 2 layout)
    const_table: float[C]
    returns      float32[P, D]      predictions

    Dispatches on spec.genome: heap populations run the level sweep
    below; postfix populations run the stack machine
    (`evaluate_population_postfix`). Both apply the same f32 primitives
    to the same operand values in the same order per node, so the two
    forms of one tree produce bitwise-identical predictions.
    """
    if spec.genome == "postfix":
        return evaluate_population_postfix(op, arg, X, const_table, spec)
    P, N = op.shape
    D = X.shape[1]
    max_depth = (N + 1).bit_length() - 2
    X = X.astype(jnp.float32)
    const_table = const_table.astype(jnp.float32)

    vals = None  # child-level buffer [P, 2**(d+1), D]
    for d in range(max_depth, -1, -1):
        lo, w = 2**d - 1, 2**d
        opd = op[:, lo:lo + w, None]  # [P, w, 1]
        argd = arg[:, lo:lo + w]
        feat = X[jnp.clip(argd, 0, X.shape[0] - 1)]  # [P, w, D] gather
        cons = const_table[jnp.clip(argd, 0, const_table.shape[0] - 1)][..., None]
        node = jnp.where(opd == prim.FEATURE, feat, jnp.broadcast_to(cons, (P, w, D)))
        if vals is not None:
            lhs, rhs = vals[:, 0::2], vals[:, 1::2]
            fn = prim.apply_function(opd, lhs, rhs, spec.fn_set)
            node = jnp.where(opd >= 3, fn, node)
        node = jnp.where(opd == prim.EMPTY, 0.0, node)
        vals = node
    return vals[:, 0]  # [P, D]


@partial(jax.jit, static_argnames=("spec",))
def evaluate_population_postfix(op, arg, X, const_table, spec: TreeSpec):
    """Stack-machine evaluation of postfix populations — the jnp
    reference for the Pallas stack kernel (kernels/gp_eval.py).

    One `lax.scan` over all NODES instruction slots carries an operand
    stack f32[P, stack_size, D] (slot 0 = top): terminals shift-push
    their value, unary functions replace the top, binary functions fold
    the top two and shift up; EMPTY slots hold the stack unchanged, so
    rows of different active lengths share the fixed-trip scan. Applies
    the identical f32 primitives (`prim.apply_function`) to the same
    operand values as the heap level sweep — bitwise-equal predictions
    for the two forms of one tree.
    """
    P, N = op.shape
    D = X.shape[1]
    S = spec.stack_size
    X = X.astype(jnp.float32)
    const_table = const_table.astype(jnp.float32)
    ARITY = jnp.asarray(prim.ARITY)

    def step(stack, xs):
        opt, argt = xs  # int32[P]
        feat = X[jnp.clip(argt, 0, X.shape[0] - 1)]  # [P, D]
        cons = const_table[jnp.clip(argt, 0, const_table.shape[0] - 1)][:, None]
        tval = jnp.where((opt == prim.FEATURE)[:, None], feat,
                         jnp.broadcast_to(cons, (P, D)))
        top = stack[:, 0]
        ar = ARITY[opt]
        lhs = jnp.where((ar == 2)[:, None], stack[:, 1], top)
        fnv = prim.apply_function(opt[:, None], lhs, top, spec.fn_set)
        push = jnp.concatenate([tval[:, None], stack[:, :S - 1]], axis=1)
        una = stack.at[:, 0].set(fnv)
        binr = jnp.concatenate(
            [fnv[:, None], stack[:, 2:], jnp.zeros((P, 1, D), jnp.float32)],
            axis=1)
        a = ar[:, None, None]
        new = jnp.where(a == 0, push, jnp.where(a == 1, una, binr))
        new = jnp.where((opt == prim.EMPTY)[:, None, None], stack, new)
        return new, None

    stack0 = jnp.zeros((P, S, D), jnp.float32)
    stack, _ = jax.lax.scan(step, stack0, (op.T, arg.T))
    return stack[:, 0]  # [P, D]; all-EMPTY rows stay 0.0 like the heap path


def evaluate_tree(op_row, arg_row, X, const_table, spec: TreeSpec):
    """Single-tree convenience wrapper (used by tests/examples)."""
    preds = evaluate_population(op_row[None], arg_row[None], X, const_table, spec)
    return preds[0]


# --- population-wide subexpression dedup (tier 1, exact) ---------------------
#
# Crossover copies subtrees verbatim across the population, so the same
# subexpression is re-evaluated over the full data axis many times per
# generation. This layer enumerates every postfix subtree span
# (trees.subtree_spans), canonicalizes each to a packed int32 signature
# (trees.subtree_signatures), dedups across the whole [P, N] population
# with one on-device sort, evaluates ONE representative per distinct
# subexpression with a level loop (operands always have strictly shorter
# spans, so length IS a topological level), and gathers each tree's root
# value back. Every unique node applies the identical
# `prim.apply_function` select chain to the identical operand bits as
# the stack interpreter, so predictions — and fitness — are BITWISE
# identical to dedup-off. Everything is fixed-shape: `cap` bounds the
# unique table, slot `cap - 1` is reserved for the all-EMPTY row root,
# and `n_unique > cap - 1` flips a single `lax.cond` onto the plain
# interpreter (still bitwise; only the plan build is wasted).


class DedupPlan(NamedTuple):
    """Fixed-shape per-generation dedup schedule (all on device).

    uop/uarg/ulen: int32[cap]  opcode / terminal arg / span length of the
                               representative node per unique slot (EMPTY/0
                               beyond ``n_unique`` and in the reserved
                               last slot)
    ulhs/urhs:     int32[cap]  unique-slot ids of the operands (binary:
                               left/right; unary: both the operand;
                               terminals: 0, never read)
    root:          int32[P]    unique-slot id of each tree's root value
                               (reserved slot ``cap - 1`` for all-EMPTY
                               rows, which stays 0.0 like the interpreter)
    n_unique:      int32[]     distinct active subexpressions found
    total:         int32[]     active subtree instances in the population
    overflow:      bool[]      n_unique exceeds the usable ``cap - 1``
    """

    uop: jnp.ndarray
    uarg: jnp.ndarray
    ulhs: jnp.ndarray
    urhs: jnp.ndarray
    ulen: jnp.ndarray
    root: jnp.ndarray
    n_unique: jnp.ndarray
    total: jnp.ndarray
    overflow: jnp.ndarray


def resolve_dedup_cap(dedup_cap: int, pop: int, num_nodes: int) -> int:
    """Static unique-table capacity. Explicit ``dedup_cap > 0`` wins;
    otherwise ``max(64, pop)`` — dedup then engages exactly when the
    population holds fewer distinct subexpressions than trees, i.e. when
    it beats evaluating every tree. Clamped to the ``P*N + 1`` slots any
    population can occupy (+1 for the reserved all-EMPTY slot)."""
    cap = dedup_cap if dedup_cap > 0 else max(64, pop)
    return int(min(cap, pop * num_nodes + 1))


@partial(jax.jit, static_argnames=("spec", "cap"))
def build_dedup_plan(op, arg, spec: TreeSpec, cap: int) -> DedupPlan:
    """Canonicalize + sort + unique the population's subtree spans into a
    fixed-shape evaluation schedule. One variadic `lax.sort` over the
    signature words (position as final tiebreak/payload) puts equal
    subexpressions adjacent; segment heads become unique slots."""
    P, N = op.shape
    T = P * N
    sig = trees_mod.subtree_signatures(op, arg, spec)  # [P, N, W]
    W = sig.shape[-1]
    sigf = sig.reshape(T, W)
    active = (op != prim.EMPTY).reshape(T)
    start = trees_mod.subtree_spans(op)
    length = jnp.arange(N, dtype=jnp.int32)[None, :] - start + 1
    lhs_i = trees_mod.postfix_lhs_index(op)

    pos = jnp.arange(T, dtype=jnp.int32)
    sorted_cols = jax.lax.sort(
        tuple(sigf[:, k] for k in range(W)) + (pos,), num_keys=W + 1)
    s_pos = sorted_cols[-1]
    is_new = jnp.zeros((T,), bool).at[0].set(True)
    for c in sorted_cols[:-1]:
        is_new = is_new | jnp.concatenate(
            [jnp.ones((1,), bool), c[1:] != c[:-1]])
    new_u = is_new & active[s_pos]  # all-zero (inactive) sigs sort first
    uid_s = jnp.cumsum(new_u.astype(jnp.int32)) - 1
    n_unique = jnp.sum(new_u.astype(jnp.int32))
    total = jnp.sum(active.astype(jnp.int32))
    # flat position -> unique id (-1 on inactive positions, never read)
    inv = jnp.zeros((T,), jnp.int32).at[s_pos].set(uid_s)
    # unique id -> representative flat position (first occurrence)
    rep = jnp.zeros((cap,), jnp.int32).at[
        jnp.where(new_u, uid_s, cap)].set(s_pos, mode="drop")

    slot = jnp.arange(cap, dtype=jnp.int32)
    valid = slot < n_unique
    rp, ri = rep // N, rep % N
    ARITY = jnp.asarray(prim.ARITY)
    uop = jnp.where(valid, op[rp, ri], prim.EMPTY).astype(jnp.int32)
    uar = ARITY[uop]
    uarg = jnp.where(valid & (uar == 0), arg[rp, ri], 0).astype(jnp.int32)
    ulen = jnp.where(valid, length[rp, ri], 0).astype(jnp.int32)

    def inv_at(flat_pos):
        return inv[jnp.clip(flat_pos, 0, T - 1)]

    # operands: right operand of any function ends at i-1; the left
    # operand of a binary ends where the right one starts, minus one
    urhs = jnp.where(uar >= 1, inv_at(rp * N + ri - 1), 0)
    ulhs = jnp.where(uar == 2, inv_at(rp * N + lhs_i[rp, ri]), urhs)

    row_len = jnp.sum((op != prim.EMPTY).astype(jnp.int32), axis=1)
    root_pos = jnp.arange(P, dtype=jnp.int32) * N + jnp.maximum(row_len - 1, 0)
    root = jnp.where(row_len > 0, inv[root_pos], cap - 1).astype(jnp.int32)
    overflow = n_unique > cap - 1
    return DedupPlan(uop, uarg, ulhs, urhs, ulen, root,
                     n_unique, total, overflow)


@partial(jax.jit, static_argnames=("spec",))
def evaluate_unique_subtrees(plan: DedupPlan, X, const_table, spec: TreeSpec):
    """f32[cap, D] value of every unique subexpression (0.0 on unused
    slots). Level loop over span length: operands of a length-l node
    have length < l, so each sweep's inputs are already final. Terminal
    lookups and the `prim.apply_function` select chain are the exact
    operations of `evaluate_population_postfix` — bitwise-equal values.
    """
    X = X.astype(jnp.float32)
    const_table = const_table.astype(jnp.float32)
    feat = X[jnp.clip(plan.uarg, 0, X.shape[0] - 1)]  # [cap, D]
    cons = const_table[jnp.clip(plan.uarg, 0, const_table.shape[0] - 1)][:, None]
    tval = jnp.where((plan.uop == prim.FEATURE)[:, None], feat,
                     jnp.broadcast_to(cons, feat.shape))
    vals = jnp.where((plan.ulen == 1)[:, None], tval, 0.0)

    def level(lvl, vals):
        lhs = vals[plan.ulhs]
        rhs = vals[plan.urhs]
        fnv = prim.apply_function(plan.uop[:, None], lhs, rhs, spec.fn_set)
        return jnp.where((plan.ulen == lvl)[:, None], fnv, vals)

    return jax.lax.fori_loop(2, jnp.max(plan.ulen) + 1, level, vals)


@partial(jax.jit, static_argnames=("spec", "cap"))
def evaluate_population_dedup(op, arg, X, const_table, spec: TreeSpec,
                              cap: int):
    """Drop-in for `evaluate_population_postfix` with cross-population
    subexpression dedup: evaluate each distinct subtree once, gather
    roots. Bitwise-identical predictions; overflow (> cap - 1 distinct
    subexpressions) falls back to the plain interpreter via `lax.cond`.
    """
    plan = build_dedup_plan(op, arg, spec, cap)
    return jax.lax.cond(
        plan.overflow,
        lambda: evaluate_population_postfix(op, arg, X, const_table, spec),
        lambda: evaluate_unique_subtrees(plan, X, const_table, spec)[plan.root])


def make_postfix_evaluator(op, arg, const_table, spec: TreeSpec,
                           dedup: str = "off", dedup_cap: int = 0):
    """Closure ``X -> f32[P, D]`` with the dedup plan built ONCE, so
    tiled/streamed fitness paths (kernels/ref.py) reuse one plan across
    every data tile. Any ``dedup != "off"`` engages the exact tier here;
    the semantic tier (engine) adds cross-generation cache keys on top.
    Non-postfix genomes always use the plain evaluator (dedup is a
    postfix-only optimization; heap trees share the front door)."""
    if dedup == "off" or spec.genome != "postfix":
        return lambda X: evaluate_population(op, arg, X, const_table, spec)
    cap = resolve_dedup_cap(dedup_cap, *op.shape)
    plan = build_dedup_plan(op, arg, spec, cap)

    def ev(X):
        return jax.lax.cond(
            plan.overflow,
            lambda: evaluate_population_postfix(op, arg, X, const_table, spec),
            lambda: evaluate_unique_subtrees(plan, X, const_table, spec)[
                plan.root])

    return ev


@partial(jax.jit, static_argnames=("spec", "cap"))
def dedup_stats(op, arg, spec: TreeSpec, cap: int):
    """(unique_subtrees, subtree_evals_saved) int32 scalars for the
    telemetry counter stream — the signature sort without the schedule
    gathers. ``saved`` is 0 when the unique table would overflow (the
    eval path then ran the plain interpreter)."""
    P, N = op.shape
    T = P * N
    sig = trees_mod.subtree_signatures(op, arg, spec).reshape(T, -1)
    W = sig.shape[-1]
    active = (op != prim.EMPTY).reshape(T)
    sorted_cols = jax.lax.sort(
        tuple(sig[:, k] for k in range(W)) + (active.astype(jnp.int32),),
        num_keys=W)
    is_new = jnp.zeros((T,), bool).at[0].set(True)
    for c in sorted_cols[:W]:
        is_new = is_new | jnp.concatenate(
            [jnp.ones((1,), bool), c[1:] != c[:-1]])
    n_unique = jnp.sum((is_new & sorted_cols[-1].astype(bool)).astype(jnp.int32))
    total = jnp.sum(active.astype(jnp.int32))
    saved = jnp.where(n_unique > cap - 1, 0, total - n_unique)
    return n_unique, saved
