"""Vectorized population evaluation — the paper's core technique, in JAX.

The paper transposes the dataset so each feature is a vector (its Eq. 1→2)
and evaluates each tree's expression as a TensorFlow graph over those
vectors. Here the *whole population* is evaluated by one level-synchronous
sweep over the heap encoding:

    for level d = max_depth .. 0:
        node_val[d] = select(opcode, f(child_vals[d+1]), terminal_vals)

Every step is a fused elementwise select over a [pop, 2**d, data] block —
one static XLA program for any population content. This module is the pure
jnp reference path; kernels/gp_eval.py is the Pallas TPU version of the
same contraction (fused with the fitness reduction), and kernels/ref.py
re-exports these functions as the kernel oracle.

Predictions are computed for EVERY data column, padded or not — dataset
padding (data/loader.pad_rows) is masked one layer up, where the
`weight: f32[D]` vector zeroes padded points out of the fitness
reduction (core/fitness partial_fitness, kernels/ref, kernels/ops, and
the Pallas kernel's w_ref all share that convention), so a padded
dataset scores exactly like the unpadded one.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import primitives as prim
from repro.core.trees import TreeSpec


@partial(jax.jit, static_argnames=("spec",))
def evaluate_population(op, arg, X, const_table, spec: TreeSpec):
    """Evaluate every tree against every data point.

    op, arg:     int32[P, N]        heap population
    X:           float[F, D]        feature-major data (the paper's Eq. 2 layout)
    const_table: float[C]
    returns      float32[P, D]      predictions
    """
    P, N = op.shape
    D = X.shape[1]
    max_depth = (N + 1).bit_length() - 2
    X = X.astype(jnp.float32)
    const_table = const_table.astype(jnp.float32)

    vals = None  # child-level buffer [P, 2**(d+1), D]
    for d in range(max_depth, -1, -1):
        lo, w = 2**d - 1, 2**d
        opd = op[:, lo:lo + w, None]  # [P, w, 1]
        argd = arg[:, lo:lo + w]
        feat = X[jnp.clip(argd, 0, X.shape[0] - 1)]  # [P, w, D] gather
        cons = const_table[jnp.clip(argd, 0, const_table.shape[0] - 1)][..., None]
        node = jnp.where(opd == prim.FEATURE, feat, jnp.broadcast_to(cons, (P, w, D)))
        if vals is not None:
            lhs, rhs = vals[:, 0::2], vals[:, 1::2]
            fn = prim.apply_function(opd, lhs, rhs, spec.fn_set)
            node = jnp.where(opd >= 3, fn, node)
        node = jnp.where(opd == prim.EMPTY, 0.0, node)
        vals = node
    return vals[:, 0]  # [P, D]


def evaluate_tree(op_row, arg_row, X, const_table, spec: TreeSpec):
    """Single-tree convenience wrapper (used by tests/examples)."""
    preds = evaluate_population(op_row[None], arg_row[None], X, const_table, spec)
    return preds[0]
