"""Primitive (opcode) table for tensorized GP trees.

Karoo GP evaluates evolved multivariate expressions. In the paper each
expression becomes a TensorFlow graph whose nodes are vectorized ops
(tf.add, tf.multiply, ...). Here the expression population is *data*:
every node is an (opcode, argument) pair in a fixed-size heap tensor, and
a single jitted interpreter evaluates all trees at once.

Opcode space
------------
  0            EMPTY      unused slot (evaluates to 0.0, never selected)
  1            CONST      terminal: const_table[arg]
  2            FEATURE    terminal: X[arg]  (arg = feature column index)
  3..          functions  (see FUNCTIONS below; unary ops ignore rhs)

Protected semantics match Karoo GP's TensorFlow operators: division,
log and sqrt are "protected" so population evaluation can never produce
NaN/Inf from a syntactically valid tree.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

# --- opcode constants -------------------------------------------------------
EMPTY = 0
CONST = 1
FEATURE = 2
_FN_BASE = 3

_EPS = 1e-9


def _protected_div(a, b):
    return jnp.where(jnp.abs(b) < _EPS, jnp.ones_like(a), a / jnp.where(jnp.abs(b) < _EPS, jnp.ones_like(b), b))


def _protected_log(a, _):
    return jnp.log(jnp.abs(a) + _EPS)


def _protected_sqrt(a, _):
    return jnp.sqrt(jnp.abs(a))


@dataclasses.dataclass(frozen=True)
class Primitive:
    name: str
    arity: int  # 1 or 2
    fn: Callable  # (lhs, rhs) -> value; unary ops ignore rhs


# Order matters: opcode = _FN_BASE + index into FUNCTIONS.
FUNCTIONS: tuple[Primitive, ...] = (
    Primitive("add", 2, lambda a, b: a + b),
    Primitive("sub", 2, lambda a, b: a - b),
    Primitive("mul", 2, lambda a, b: a * b),
    Primitive("div", 2, _protected_div),
    Primitive("neg", 1, lambda a, _: -a),
    Primitive("abs", 1, lambda a, _: jnp.abs(a)),
    Primitive("sin", 1, lambda a, _: jnp.sin(a)),
    Primitive("cos", 1, lambda a, _: jnp.cos(a)),
    Primitive("sqrt", 1, _protected_sqrt),
    Primitive("log", 1, _protected_log),
    Primitive("square", 1, lambda a, _: a * a),
    Primitive("min", 2, jnp.minimum),
    Primitive("max", 2, jnp.maximum),
)

N_OPCODES = _FN_BASE + len(FUNCTIONS)
FN_NAMES = tuple(p.name for p in FUNCTIONS)
ARITY = np.array([0, 0, 0] + [p.arity for p in FUNCTIONS], dtype=np.int32)


def opcode_of(name: str) -> int:
    return _FN_BASE + FN_NAMES.index(name)


@dataclasses.dataclass(frozen=True)
class FunctionSet:
    """A user-selected subset of FUNCTIONS, as opcode arrays.

    Karoo GP lets each run choose its operator set (the paper's runs use
    arithmetic +-*/ for regression and a wider set for classification).
    """

    opcodes: np.ndarray  # int32[num_fns] opcodes drawn from FUNCTIONS
    name: str = "custom"

    @staticmethod
    def make(names: Sequence[str], name: str = "custom") -> "FunctionSet":
        return FunctionSet(np.array([opcode_of(n) for n in names], dtype=np.int32), name)

    @property
    def binary_opcodes(self) -> np.ndarray:
        return self.opcodes[ARITY[self.opcodes] == 2]

    @property
    def unary_opcodes(self) -> np.ndarray:
        return self.opcodes[ARITY[self.opcodes] == 1]


ARITHMETIC = FunctionSet.make(("add", "sub", "mul", "div"), "arithmetic")
KITCHEN_SINK = FunctionSet.make(FN_NAMES, "kitchen_sink")
CLASSIFY_SET = FunctionSet.make(("add", "sub", "mul", "div", "abs", "min", "max"), "classify")


def apply_function(op, lhs, rhs, fn_set: "FunctionSet | None" = None):
    """Elementwise select over function opcodes.

    op:        int array broadcastable against lhs/rhs
    lhs, rhs:  float arrays (children values)
    fn_set:    restrict the select chain to a run's operator set — a
               population generated from k operators only ever contains
               those opcodes, so evaluating the other 13-k branches is
               pure waste (§Perf iteration: the compute term scales with
               the branch count).

    Computes each candidate primitive then selects — the standard
    vectorized-interpreter trade (VPU ops instead of branchy control
    flow). This is exactly what makes the whole population a single
    static XLA program.
    """
    if fn_set is not None:
        codes = [int(c) for c in fn_set.opcodes]
    else:
        codes = list(range(_FN_BASE, _FN_BASE + len(FUNCTIONS)))
    branches = [FUNCTIONS[c - _FN_BASE].fn(lhs, rhs) for c in codes]
    preds = [op == c for c in codes]
    return jnp.select(preds, branches, jnp.zeros_like(lhs))
