"""Mamba-2 SSD (state-space duality) block — chunked dual form for
training/prefill, O(1)-state recurrence for decode.

Follows the SSD algorithm of arXiv:2405.21060 §6: the sequence is split
into chunks; within a chunk the (semi-separable) attention-like quadratic
form runs on the MXU, and a short `lax.scan` passes the [B, H, d_state,
headdim] state between chunks. This is the sub-quadratic path that makes
the `long_500k` cells feasible (KV-free decode).

Jamba's mamba layers reuse this block (Jamba-1.5 ships Mamba-1 layers; we
substitute SSD as the TPU-native equivalent and note it in DESIGN.md).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm


@dataclasses.dataclass(frozen=True)
class SSMDims:
    d_model: int
    d_state: int = 128
    headdim: int = 64
    n_groups: int = 1
    expand: int = 2
    d_conv: int = 4
    chunk: int = 128
    scan_block: int = 4096  # macro-block: bounds SSD transients at long seq

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.headdim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def ssm_init(key, dims: SSMDims, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * dims.d_inner + 2 * dims.n_groups * dims.d_state + dims.n_heads
    return {
        "in_proj": dense_init(ks[0], (dims.d_model, d_in_proj), (0,), dtype),
        "conv_w": dense_init(ks[1], (dims.d_conv, dims.conv_dim), (0,), dtype),
        "conv_b": jnp.zeros((dims.conv_dim,), dtype),
        "A_log": jnp.zeros((dims.n_heads,), jnp.float32),
        "D": jnp.ones((dims.n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((dims.n_heads,), jnp.float32),
        "norm": jnp.ones((dims.d_inner,), dtype),
        "out_proj": dense_init(ks[3], (dims.d_inner, dims.d_model), (0,), dtype),
    }


def _split_zxbcdt(zxbcdt, dims: SSMDims):
    di, gn, h = dims.d_inner, dims.n_groups * dims.d_state, dims.n_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * gn]
    dt = zxbcdt[..., di + di + 2 * gn:]
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv over seq. xBC: [B, L, Cd]; w: [K, Cd]."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def _segsum(a):
    """a: [..., T] log-decays → [..., T, T] with S[i,j] = sum_{j<k<=i} a_k
    (lower-triangular; -inf above diagonal)."""
    T = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    s = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, s, -jnp.inf)


def ssd_chunked(x, dt, A_log, B, C, D, chunk: int, h0=None, policy=None):
    """SSD dual-form scan.

    x: [b,l,h,p]  dt: [b,l,h] (post-softplus)  A_log: [h]
    B, C: [b,l,g,n]  D: [h]  h0: [b,h,n,p] initial state (macro-block carry)
    → (y [b,l,h,p], final_state [b,h,n,p])
    """
    b, l0, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    # pad ragged lengths with dt=0 steps: decay exp(0)=1 and B·dt=0, so the
    # state passes through padding untouched and y[:l0] is exact
    pad = (-l0) % chunk
    if pad:
        padl = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        x, dt, B, C = padl(x), padl(dt), padl(B), padl(C)
    l = l0 + pad
    nc = l // chunk
    rep = h // g
    a = (-jnp.exp(A_log))[None, None, :] * dt  # [b,l,h] log decay

    def shard_h(t, axis):  # pin head-parallel layout (TP over SSM heads)
        if policy is None or h % policy.tp_size:
            return t
        from jax.sharding import PartitionSpec as P
        spec = [None] * t.ndim
        spec[0] = policy.batch
        spec[axis] = policy.model
        return jax.lax.with_sharding_constraint(t, P(*spec))

    xc = shard_h(x.reshape(b, nc, chunk, h, p), 3)
    dtc = shard_h(dt.reshape(b, nc, chunk, h), 3)
    ac = shard_h(a.reshape(b, nc, chunk, h), 3)
    Bh = shard_h(jnp.repeat(B.reshape(b, nc, chunk, g, n), rep, axis=3), 3)
    Ch = shard_h(jnp.repeat(C.reshape(b, nc, chunk, g, n), rep, axis=3), 3)

    a_cum = jnp.cumsum(ac, axis=2)  # [b,nc,cl,h]
    # --- intra-chunk (the attention-like quadratic form, MXU-friendly) -----
    Ldec = jnp.exp(_segsum(ac.transpose(0, 1, 3, 2)))  # [b,nc,h,cl,cl]
    S = jnp.einsum("bzihn,bzjhn->bzhij", Ch, Bh, preferred_element_type=jnp.float32)
    M = S * Ldec
    xdt = xc * dtc[..., None]
    Ydiag = jnp.einsum("bzhij,bzjhp->bzihp", M.astype(x.dtype), xdt,
                       preferred_element_type=jnp.float32)

    # --- chunk-final states ---------------------------------------------------
    decay_states = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # [b,nc,cl,h]
    states = jnp.einsum("bzjhn,bzjhp->bzhnp",
                        (Bh * (dtc * decay_states)[..., None]).astype(x.dtype),
                        xc, preferred_element_type=jnp.float32)  # [b,nc,h,n,p]
    states = shard_h(states, 2)

    # --- inter-chunk recurrence (short scan over nc) --------------------------
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])  # [b,nc,h]

    def body(carry, inp):
        st, dec = inp  # [b,h,n,p], [b,h]
        prev = carry
        new = prev * dec[:, :, None, None] + st
        return new, prev

    init = h0 if h0 is not None else jnp.zeros((b, h, n, p), jnp.float32)
    final, prev_states = jax.lax.scan(
        body, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = shard_h(prev_states.transpose(1, 0, 2, 3, 4), 2)  # [b,nc,h,n,p]

    # --- state → output (off-diagonal term) ----------------------------------
    Yoff = jnp.einsum("bzihn,bzhnp->bzihp", Ch * jnp.exp(a_cum)[..., None],
                      prev_states.astype(x.dtype), preferred_element_type=jnp.float32)

    y = (Ydiag + Yoff).reshape(b, l, h, p).astype(x.dtype)
    y = y + D[None, None, :, None] * x
    return y[:, :l0], final


def ssm_apply(p, x, dims: SSMDims, policy=None):
    """Train/prefill. x: [B, L, d] → (y [B, L, d], final_state, conv_tail).

    Sequences longer than `dims.scan_block` are processed in macro-blocks
    under a state-carrying `lax.scan`, bounding the SSD transients
    (decay matrices, chunk states) to one block — this is what makes the
    32k-prefill and 500k cells fit HBM."""
    B, L, _ = x.shape
    zxbcdt = jnp.einsum("bld,de->ble", x, p["in_proj"])
    z, xBC, dt = _split_zxbcdt(zxbcdt, dims)
    conv_tail = xBC[:, -(dims.d_conv - 1):, :]  # decode warm-start
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    di, gn = dims.d_inner, dims.n_groups * dims.d_state
    xs = xBC[..., :di].reshape(B, L, dims.n_heads, dims.headdim)
    Bm = xBC[..., di:di + gn].reshape(B, L, dims.n_groups, dims.d_state)
    Cm = xBC[..., di + gn:].reshape(B, L, dims.n_groups, dims.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])

    blk = dims.scan_block
    if L > blk and L % blk == 0:
        nb = L // blk

        def body(h, inp):
            xs_b, dt_b, Bm_b, Cm_b = inp
            y_b, h_new = ssd_chunked(xs_b, dt_b, p["A_log"], Bm_b, Cm_b, p["D"],
                                     dims.chunk, h0=h, policy=policy)
            return h_new, y_b

        split = lambda t: t.reshape((B, nb, blk) + t.shape[2:]).swapaxes(0, 1)
        final, ys = jax.lax.scan(
            body, jnp.zeros((B, dims.n_heads, dims.d_state, dims.headdim),
                            jnp.float32),
            (split(xs), split(dt), split(Bm), split(Cm)))
        y = ys.swapaxes(0, 1).reshape(B, L, dims.n_heads, dims.headdim)
    else:
        y, final = ssd_chunked(xs, dt, p["A_log"], Bm, Cm, p["D"], dims.chunk,
                               policy=policy)
    y = y.reshape(B, L, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return jnp.einsum("ble,ed->bld", y, p["out_proj"]), final, conv_tail


def ssm_decode(p, x, ssm_state, conv_state, dims: SSMDims):
    """Single-token recurrence. x: [B, 1, d]; ssm_state: [B, H, N, P] f32;
    conv_state: [B, d_conv-1, conv_dim]. Returns (y, new_ssm, new_conv)."""
    B = x.shape[0]
    zxbcdt = jnp.einsum("bld,de->ble", x, p["in_proj"])
    z, xBC, dt = _split_zxbcdt(zxbcdt, dims)
    window = jnp.concatenate([conv_state, xBC.astype(conv_state.dtype)], axis=1)
    new_conv = window[:, 1:]
    conv_out = jax.nn.silu((window * p["conv_w"][None]).sum(1) + p["conv_b"])  # [B, Cd]
    di, gn = dims.d_inner, dims.n_groups * dims.d_state
    xs = conv_out[:, :di].reshape(B, dims.n_heads, dims.headdim)
    Bm = conv_out[:, di:di + gn].reshape(B, dims.n_groups, dims.d_state)
    Cm = conv_out[:, di + gn:].reshape(B, dims.n_groups, dims.d_state)
    rep = dims.n_heads // dims.n_groups
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)  # [B, H, N]
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B, H]
    dA = jnp.exp(-jnp.exp(p["A_log"])[None] * dt)  # [B, H]
    upd = (dt[..., None] * Bh)[..., :, None] * xs.astype(jnp.float32)[:, :, None, :]
    new_state = ssm_state * dA[..., None, None] + upd  # [B,H,N,P]
    y = jnp.einsum("bhn,bhnp->bhp", Ch, new_state) + p["D"][None, :, None] * xs
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return jnp.einsum("ble,ed->bld", y, p["out_proj"]), new_state, new_conv
