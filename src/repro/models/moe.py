"""Mixture-of-Experts FFN: token-choice top-k routing, capacity-bounded,
sort-based dispatch (dropless up to the capacity factor).

Two dispatch paths:

  moe_apply          single-device / small-token path: sort + scatter into
                     an [E, C, d] buffer. Correct everywhere, but under
                     GSPMD auto-partitioning the scatter/gather lowers to
                     DENSE [T·k, d] u32 index maps — 60+ GB/device at
                     qwen3-moe's 1M-token training batch.
  moe_apply_sharded  production path: explicit `shard_map`. Tokens stay
                     sharded on the batch axes, dispatch scatters are
                     shard-LOCAL (tiny), expert parallelism is a real
                     `all_to_all` over the model axis, and the FSDP dim of
                     the expert weights is all-gathered in-block. This is
                     the TPU-native mapping of token-choice MoE (DESIGN.md
                     §4); non-divisible expert counts (granite's 40 on a
                     16-way axis) are zero-padded to the axis size with
                     router logits pinned to -inf for dead experts.

_apply_mlp picks the sharded path whenever a policy is installed and the
shapes divide; tests pin both paths against the same dense reference.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models.layers import dense_init


def moe_init(key, d_model: int, d_ff: int, n_experts: int, *, gated=True,
             dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d_model, n_experts), (0,), jnp.float32),
        "w_up": dense_init(ks[1], (n_experts, d_model, d_ff), (1,), dtype),
        "w_down": dense_init(ks[2], (n_experts, d_ff, d_model), (1,), dtype),
    }
    if gated:
        p["w_gate"] = dense_init(ks[3], (n_experts, d_model, d_ff), (1,), dtype)
    return p


def capacity(tokens: int, top_k: int, n_experts: int, factor: float = 1.25) -> int:
    c = int(math.ceil(tokens * top_k / n_experts * factor))
    return max(8, -(-c // 8) * 8)  # round up to 8 for lane alignment


def _expert_ffn(buf, p_up, p_gate, p_down, act: str):
    """buf: [E, C, d] → [E, C, d] through the per-expert gated FFN."""
    up = jnp.einsum("ecd,edf->ecf", buf, p_up)
    if p_gate is not None:
        g = jnp.einsum("ecd,edf->ecf", buf, p_gate)
        h = (jax.nn.gelu(g) if act == "gelu" else jax.nn.silu(g)) * up
    else:
        h = jax.nn.gelu(up) if act == "gelu" else jax.nn.silu(up)
    return jnp.einsum("ecf,efd->ecd", h, p_down)


def _dispatch_combine(xt, logits, top_k: int, C: int, E: int, ffn):
    """Shared local dispatch: sort-by-expert, capacity-bounded scatter,
    expert FFN callback, weighted combine. xt: [T, d] (local)."""
    T, d = xt.shape
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_e = jax.lax.top_k(probs, top_k)  # [T, k]
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style), local statistics
    me = jnp.zeros((E,)).at[gate_e.reshape(-1)].add(1.0) / (T * top_k)
    pe = probs.mean(0)
    aux = E * jnp.sum(me * pe)

    flat_e = gate_e.reshape(T * top_k)
    flat_t = jnp.arange(T * top_k) // top_k
    order = jnp.argsort(flat_e, stable=True)
    se, st = flat_e[order], flat_t[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * top_k, dtype=jnp.int32) - starts[se]
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)  # overflow slot dropped

    buf = jnp.zeros((E * C + 1, d), xt.dtype).at[slot].set(xt[st])
    out = ffn(buf[: E * C].reshape(E, C, d))  # [E, C, d]

    vals = out.reshape(E * C, d)[jnp.clip(slot, 0, E * C - 1)]
    w = (gate_w.reshape(T * top_k)[order] * keep).astype(xt.dtype)
    y = jnp.zeros((T, d), xt.dtype).at[st].add(vals * w[:, None])
    return y, aux


def moe_apply(p, x, *, top_k: int, act: str = "silu", capacity_factor: float = 1.25):
    """Reference path. x: [B, S, d] -> (y [B, S, d], aux_loss scalar)."""
    B, S, d = x.shape
    T = B * S
    E = p["router"].shape[1]
    C = capacity(T, top_k, E, capacity_factor)
    xt = x.reshape(T, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    ffn = lambda buf: _expert_ffn(buf, p["w_up"], p.get("w_gate"), p["w_down"], act)
    y, aux = _dispatch_combine(xt, logits, top_k, C, E, ffn)
    return y.reshape(B, S, d), aux


def moe_apply_sharded(p, x, *, top_k: int, act: str = "silu",
                      capacity_factor: float = 1.25, policy=None):
    """Explicit-EP path (see module docstring). Requires: policy set, batch
    divisible by the batch axes, E (padded) divisible by the model axis."""
    B, S, d = x.shape
    E = p["router"].shape[1]
    tp = policy.tp_size
    E_pad = -(-E // tp) * tp  # zero-pad dead experts (granite: 40 -> 48)
    batch_axes = tuple(policy.batch)
    model_ax = policy.model
    # Shard tokens over the model axis too when the sequence divides: this
    # matches the seq-sharded residual layout (zero resharding on entry)
    # and — critically — dispatches each token ONCE. With batch-only
    # sharding every model rank re-dispatches the same tokens: correct,
    # but tp× redundant compute (§Perf iteration 1).
    seq_sharded = S % tp == 0 and S > 1
    n_shards = policy.dp_size * (tp if seq_sharded else 1)
    T_loc = (B * S) // n_shards
    C_loc = capacity(T_loc, top_k, E_pad, capacity_factor)

    gated = "w_gate" in p

    def block(x_l, router, w_up, w_gate, w_down):
        # x_l: [B_loc, S, d]; w_*: [E_loc, d_loc_fsdp, f] local shards
        T = x_l.shape[0] * x_l.shape[1]
        xt = x_l.reshape(T, d)
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
        if E_pad > E:
            logits = jnp.pad(logits, ((0, 0), (0, E_pad - E)),
                             constant_values=-jnp.inf)

        # gather the FSDP shard of the expert weights (ZeRO-3 style)
        w_up = jax.lax.all_gather(w_up, batch_axes, axis=1, tiled=True)
        w_down = jax.lax.all_gather(w_down, batch_axes, axis=1, tiled=True)
        w_gate_g = (jax.lax.all_gather(w_gate, batch_axes, axis=1, tiled=True)
                    if gated else None)

        # checkpoint: the expert hiddens ([C·tp, ff], the largest activation
        # in MoE training) are recomputed in backward instead of saved
        expert_ffn = jax.checkpoint(
            lambda b: _expert_ffn(b, w_up, w_gate_g, w_down, act))

        def ffn(buf):  # buf: [E_pad, C_loc, d] local
            # all_to_all: experts scatter to their owner rank; tokens from
            # every rank concatenate on the capacity axis
            buf = jax.lax.all_to_all(buf, model_ax, split_axis=0, concat_axis=1,
                                     tiled=True)  # [E_loc, C_loc*tp, d]
            out = expert_ffn(buf)
            return jax.lax.all_to_all(out, model_ax, split_axis=1, concat_axis=0,
                                      tiled=True)  # [E_pad, C_loc, d]

        y, aux = _dispatch_combine(xt, logits, top_k, C_loc, E_pad, ffn)
        aux = jax.lax.pmean(aux, batch_axes + ((model_ax,) if seq_sharded else ()))
        return y.reshape(x_l.shape), aux

    fs = batch_axes
    wspec = P(model_ax, fs, None)
    xspec = (P(batch_axes, model_ax, None) if seq_sharded
             else P(batch_axes, None, None))
    out_y, aux = compat.shard_map(
        block,
        in_specs=(xspec, P(None, None), wspec, wspec, wspec),
        out_specs=(xspec, P()),
    )(x, p["router"], _pad_e(p["w_up"], E_pad),
      _pad_e(p.get("w_gate"), E_pad) if gated else _zero_like_up(p, E_pad),
      _pad_e(p["w_down"], E_pad))
    return out_y, aux


def _pad_e(w, E_pad):
    if w is None or w.shape[0] == E_pad:
        return w
    return jnp.pad(w, ((0, E_pad - w.shape[0]), (0, 0), (0, 0)))


def _zero_like_up(p, E_pad):
    w = p["w_up"]
    return jnp.zeros((E_pad,) + w.shape[1:], w.dtype)


def sharded_path_ok(policy, x_shape, n_experts: int) -> bool:
    """Static check: can moe_apply_sharded run for these shapes?"""
    if policy is None:
        return False
    B, S, _ = x_shape
    return (B * S) % policy.dp_size == 0 and B % policy.dp_size == 0
