"""ArchConfig + model assembly + step factories + dry-run input specs.

`build_model(cfg)` returns a functional Model whose methods close over the
config only — params/caches are explicit pytrees, so `jax.eval_shape` can
drive the whole multi-pod dry-run without allocating a byte.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import transformer as T
from repro.models.layers import AttnDims
from repro.models.ssm import SSMDims
from repro.models.transformer import ShardingPolicy

# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    d_ff: int
    vocab: int
    act: str = "silu"
    gated_mlp: bool = True
    qkv_bias: bool = False
    rope_theta: float = 1e4
    pos_embed: str = "rope"  # rope | sinusoidal
    norm: str = "rms"  # rms | ln
    norm_plus_one: bool = False
    embed_scale: bool = False
    tie_embeddings: bool = False
    # moe
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    # ssm
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 128
    # structure: pattern repeated n_layers/len(pattern) times
    pattern: tuple = (("attn", "dense"),)
    enc_layers: int = 0  # whisper encoder depth
    n_memory: int = 0  # cross-attn memory tokens (enc output / image patches)
    # attention chunking
    q_chunk: int = 512
    kv_chunk: int = 1024
    # numerics / optimizer
    compute_dtype: str = "bfloat16"
    cache_dtype: str = "bfloat16"
    optimizer: str = "adamw"  # adamw | adafactor
    moe_capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    accum_steps: int = 1
    # sharding (None → no constraints; launch/* installs a policy)
    policy: ShardingPolicy | None = None
    # shape-cell support (full attention archs skip long_500k)
    subquadratic: bool = False

    @property
    def attn_dims(self) -> AttnDims:
        return AttnDims(self.d_model, self.n_heads, self.n_kv, self.d_head,
                        self.qkv_bias, self.rope_theta)

    @property
    def ssm_dims(self) -> SSMDims:
        return SSMDims(self.d_model, self.ssm_state, self.ssm_headdim,
                       self.ssm_groups, chunk=self.ssm_chunk)

    @property
    def n_groups(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (self.n_layers, self.pattern)
        return self.n_layers // len(self.pattern)

    def with_policy(self, policy: ShardingPolicy | None) -> "ArchConfig":
        return dataclasses.replace(self, policy=policy)

    def param_count(self) -> int:
        shapes = jax.eval_shape(lambda k: init_params(self, k), jax.random.PRNGKey(0))
        return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of moe_experts)."""
        total = self.param_count()
        if not self.moe_experts:
            return total
        expert = 0
        n_moe_layers = sum(1 for _, ml in self.pattern if ml == "moe") * self.n_groups
        per = self.d_model * self.moe_d_ff * (3 if self.gated_mlp else 2)
        expert = n_moe_layers * per
        return total - expert * self.moe_experts + expert * self.moe_top_k


# --------------------------------------------------------------------------
# params / forward
# --------------------------------------------------------------------------


def _dtype(cfg):
    return jnp.dtype(cfg.compute_dtype)


def _sinusoidal(max_len, d, dtype):
    pos = np.arange(max_len)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, dtype)


def init_params(cfg: ArchConfig, key):
    """Full parameter pytree (f32 master copies; cast to compute dtype in fwd)."""
    ks = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "tok": T.embed_init(cfg, ks[0], jnp.float32),
        "stack": T.stack_init(cfg, ks[1], cfg.pattern, cfg.n_groups, jnp.float32),
        "final_norm": T._norm_init(cfg, jnp.float32),
    }
    if cfg.family == "encdec":
        enc_pattern = (("attn_full", "dense"),)
        params["enc_stack"] = T.stack_init(cfg, ks[2], enc_pattern, cfg.enc_layers,
                                           jnp.float32)
        params["enc_norm"] = T._norm_init(cfg, jnp.float32)
    return params


def _cast(params, dtype):
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a, params)


def _encode_memory(cfg, params, batch):
    """Cross-attention memory: whisper runs the encoder over (stubbed) frame
    embeddings; VLM consumes (stubbed) patch embeddings directly."""
    if cfg.family == "encdec":
        mem = batch["frames"].astype(_dtype(cfg))
        mem = mem + _sinusoidal(mem.shape[1], cfg.d_model, mem.dtype)[None]
        mem, _ = T.stack_apply_train(cfg, _cast(params["enc_stack"], _dtype(cfg)), mem,
                                     (("attn_full", "dense"),), causal=False)
        return T._apply_norm(cfg, _cast(params["enc_norm"], _dtype(cfg)), mem)
    if cfg.family == "vlm":
        return batch["memory"].astype(_dtype(cfg))
    return None


def forward_train(cfg: ArchConfig, params, batch):
    """batch: tokens [B,S], labels [B,S], mask [B,S] (+frames|memory)."""
    dt = _dtype(cfg)
    p = _cast(params, dt)
    x = T.embed_tokens(cfg, p["tok"], batch["tokens"])
    if cfg.pos_embed == "sinusoidal":
        x = x + _sinusoidal(x.shape[1], cfg.d_model, x.dtype)[None]
    if cfg.policy:
        x = jax.lax.with_sharding_constraint(x, P(cfg.policy.batch, None, None))
    memory = _encode_memory(cfg, p, batch)
    x, aux = T.stack_apply_train(cfg, p["stack"], x, cfg.pattern, memory=memory)
    x = T._apply_norm(cfg, p["final_norm"], x)
    ce = T.chunked_ce_loss(cfg, p["tok"], x, batch["labels"], batch["mask"])
    loss = ce + cfg.aux_loss_weight * aux
    return loss, {"ce": ce, "aux": aux}


# --------------------------------------------------------------------------
# serve: cache init / prefill / decode
# --------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    return T.stack_cache_init(cfg, cfg.pattern, cfg.n_groups, batch, max_len,
                              jnp.dtype(cfg.cache_dtype))


def decode_step(cfg: ArchConfig, params, cache, token, cur_len):
    """One token for every sequence. token: [B,1] int32; cur_len: [] int32.
    Returns (logits [B,1,V], new_cache)."""
    dt = _dtype(cfg)
    p = _cast(params, dt)
    x = T.embed_tokens(cfg, p["tok"], token)
    if cfg.pos_embed == "sinusoidal":
        pe = _sinusoidal(cache_max_len(cache), cfg.d_model, x.dtype)
        x = x + jax.lax.dynamic_slice_in_dim(pe, cur_len, 1)[None]
    x, new_cache = T.stack_apply_decode(cfg, p["stack"], x, cache, cur_len, cfg.pattern)
    x = T._apply_norm(cfg, p["final_norm"], x)
    return T.logits_last(cfg, p["tok"], x), new_cache


def cache_max_len(cache) -> int:
    for k in cache:
        if "k" in cache[k]:
            return cache[k]["k"].shape[2]
    return 1


def prefill(cfg: ArchConfig, params, batch, max_len: int):
    """Process the full prompt, build the cache, return last-token logits.

    tokens: [B, S] → (logits [B,1,V], cache, cur_len=S).
    """
    dt = _dtype(cfg)
    p = _cast(params, dt)
    tokens = batch["tokens"]
    B, Sq = tokens.shape
    x = T.embed_tokens(cfg, p["tok"], tokens)
    if cfg.pos_embed == "sinusoidal":
        x = x + _sinusoidal(Sq, cfg.d_model, x.dtype)[None]
    if cfg.policy:
        x = jax.lax.with_sharding_constraint(x, P(cfg.policy.batch, None, None))
    memory = _encode_memory(cfg, p, batch)
    x, cache = T.stack_apply_prefill(cfg, p["stack"], x, cfg.pattern, max_len,
                                     jnp.dtype(cfg.cache_dtype), memory=memory)
    x = T._apply_norm(cfg, p["final_norm"], x)
    logits = T.logits_last(cfg, p["tok"], x[:, -1:])
    return logits, cache


# --------------------------------------------------------------------------
# step factories
# --------------------------------------------------------------------------


def make_train_step(cfg: ArchConfig, optimizer, param_specs=None) -> Callable:
    """(train_state, batch) → (train_state, metrics). Optimizer from
    repro.optim (init/update pair). Supports gradient accumulation.

    `param_specs` (a pytree of PartitionSpec) pins the gradient layout to
    the parameter layout — without it SPMD may replicate the stacked
    [n_groups, ...] grad accumulators of the scan backward, which is a
    >100 GB/device bug at 123B params."""

    def loss_fn(params, batch):
        return forward_train(cfg, params, batch)

    def constrain(grads):
        if param_specs is None:
            return grads
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s), grads, param_specs)

    def train_step(state, batch):
        params, opt_state, step = state["params"], state["opt"], state["step"]
        if cfg.accum_steps > 1:
            def micro(c, mb):
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g = constrain(g)
                return jax.tree.map(jnp.add, c, (g, l)), m
            B = batch["tokens"].shape[0]
            mb = jax.tree.map(
                lambda a: a.reshape((cfg.accum_steps, B // cfg.accum_steps) + a.shape[1:]),
                batch)
            zero = (jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), params),
                    jnp.zeros((), jnp.float32))
            (grads, loss), ms = jax.lax.scan(micro, zero, mb)
            grads = jax.tree.map(lambda g: g / cfg.accum_steps, grads)
            loss = loss / cfg.accum_steps
            metrics = jax.tree.map(lambda a: a.mean(), ms)
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        grads = constrain(grads)
        new_params, new_opt = optimizer.update(grads, opt_state, params, step)
        new_state = {"params": new_params, "opt": new_opt, "step": step + 1}
        metrics = {"loss": loss, **metrics,
                   "grad_norm": jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                                             for g in jax.tree.leaves(grads)))}
        return new_state, metrics

    return train_step


def make_serve_step(cfg: ArchConfig) -> Callable:
    def serve_step(params, cache, token, cur_len):
        return decode_step(cfg, params, cache, token, cur_len)

    return serve_step


def build_model(cfg: ArchConfig):
    """Bundle the functional API for one architecture."""
    return {
        "config": cfg,
        "init_params": lambda key: init_params(cfg, key),
        "forward_train": lambda p, b: forward_train(cfg, p, b),
        "prefill": lambda p, b, m: prefill(cfg, p, b, m),
        "decode_step": lambda p, c, t, l: decode_step(cfg, p, c, t, l),
        "init_cache": lambda b, m: init_cache(cfg, b, m),
    }


# --------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct stand-ins, no allocation)
# --------------------------------------------------------------------------

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def shape_supported(cfg: ArchConfig, shape: str) -> bool:
    if shape == "long_500k" and not cfg.subquadratic:
        return False  # full-attention archs skip (DESIGN.md §5)
    return True


def input_specs(cfg: ArchConfig, shape: str):
    """ShapeDtypeStructs for every model input of a (arch × shape) cell.

    Returns (kind, specs_dict). kind ∈ {train, prefill, decode} selects
    which step function the dry-run lowers.
    """
    s = SHAPES[shape]
    B, S = s["batch"], s["seq"]
    f32, i32 = jnp.float32, jnp.int32
    sds = jax.ShapeDtypeStruct
    if s["kind"] == "train":
        specs = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32),
                 "mask": sds((B, S), f32)}
        if cfg.family == "encdec":
            specs["frames"] = sds((B, S, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            specs["memory"] = sds((B, cfg.n_memory, cfg.d_model), jnp.bfloat16)
        return "train", specs
    if s["kind"] == "prefill":
        specs = {"tokens": sds((B, S), i32)}
        if cfg.family == "encdec":
            specs["frames"] = sds((B, cfg.n_memory, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            specs["memory"] = sds((B, cfg.n_memory, cfg.d_model), jnp.bfloat16)
        return "prefill", specs
    # decode: one new token against a seq_len cache
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
    return "decode", {
        "cache": cache,
        "token": sds((B, 1), i32),
        "cur_len": sds((), i32),
    }
