"""Shared neural layers: norms, RoPE, chunked (flash-style) attention, MLPs.

Everything is functional — params are plain dicts of arrays — and every
op is expressed so XLA's SPMD partitioner can shard it from the pjit
in_shardings alone. Attention never materializes an [S, S] score matrix:
training/prefill use a q-chunk × kv-chunk double `lax.scan` with running
max/denominator (memory-efficient "flash" contraction in pure JAX — the
TPU-native replacement for a CUDA flash kernel, DESIGN.md §2), and decode
does a single-token pass that supports a sequence-sharded KV cache.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# initializers / norms
# --------------------------------------------------------------------------


def dense_init(key, shape, in_axes=(0,), dtype=jnp.float32):
    fan_in = max(int(np.prod([shape[a] for a in in_axes])), 1)
    return (jax.random.normal(key, shape, jnp.float32) / math.sqrt(fan_in)).astype(dtype)


def rms_norm(x, scale, *, eps: float = 1e-6, plus_one: bool = False):
    x32 = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    s = (1.0 + scale) if plus_one else scale
    return (x32 * inv).astype(x.dtype) * s.astype(x.dtype)


def layer_norm(x, scale, bias, *, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * scale.astype(x.dtype) + bias.astype(x.dtype)


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------


def rope(x, positions, *, theta: float = 10000.0):
    """x: [..., S, H, hd]; positions: [..., S] (broadcastable)."""
    hd = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # [..., S, 1, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# chunked causal/full attention (training & prefill)
# --------------------------------------------------------------------------


def _chunk_attend(q, k, v, mask, scale):
    """q:[B,Hq,Lq,hd] k,v:[B,Hkv,Lk,hd] mask:[Lq,Lk] bool|None.
    Returns (o_unnormalized [B,Hq,Lq,hd] f32, m [B,Hq,Lq] f32, l [B,Hq,Lq] f32)."""
    groups = q.shape[1] // k.shape[1]
    kq = jnp.repeat(k, groups, axis=1)
    vq = jnp.repeat(v, groups, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kq, preferred_element_type=jnp.float32) * scale
    if mask is not None:
        s = jnp.where(mask[None, None], s, -jnp.inf)
    m = jnp.max(s, axis=-1)  # may be -inf for fully-masked rows
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    l = p.sum(-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), vq,
                   preferred_element_type=jnp.float32)
    return o, m_safe, l


def chunked_attention(q, k, v, *, causal: bool, q_chunk: int = 512, kv_chunk: int = 1024,
                      positions_q=None, positions_k=None, policy=None):
    """Memory-efficient attention. q:[B,S_q,Hq,hd] k,v:[B,S_k,Hkv,hd] →
    [B,S_q,Hq,hd]. Never materializes more than [B,H,q_chunk,kv_chunk]."""
    B, Sq0, Hq, hd = q.shape
    Sk0 = k.shape[1]
    q_chunk = min(q_chunk, Sq0)
    kv_chunk = min(kv_chunk, Sk0)
    # pad ragged lengths (e.g. whisper's 1500-frame memory) up to the tile;
    # padded keys are masked out via sentinel positions, padded queries cut.
    pad_q = (-Sq0) % q_chunk
    pad_k = (-Sk0) % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sq, Sk = Sq0 + pad_q, Sk0 + pad_k
    kv_valid = jnp.arange(Sk) < Sk0
    scale = 1.0 / math.sqrt(hd)
    # [B,S,H,d] -> [B,H,S,d] once, chunk on S
    qT = q.transpose(0, 2, 1, 3)
    kT = k.transpose(0, 2, 1, 3)
    vT = v.transpose(0, 2, 1, 3)
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    qs = qT.reshape(B, Hq, nq, q_chunk, hd).transpose(2, 0, 1, 3, 4)  # [nq,B,H,qc,hd]
    ks = kT.reshape(B, kT.shape[1], nk, kv_chunk, hd).transpose(2, 0, 1, 3, 4)
    vs = vT.reshape(B, vT.shape[1], nk, kv_chunk, hd).transpose(2, 0, 1, 3, 4)
    if policy is not None:
        # pin the chunk-scan xs layout: heads on the model axis. Left to
        # itself GSPMD shards the loop-invariant K/V stacks on d_head, which
        # makes every score dot a partial sum + [B,H,qc,kc] all-reduce over
        # the model axis — tens of TB per 32k prefill (§Perf iteration 2).
        from jax.sharding import PartitionSpec as _P

        def pin(t, *, allow_row_shard=False):
            if t.shape[2] % policy.tp_size == 0:
                return jax.lax.with_sharding_constraint(
                    t, _P(None, policy.batch, policy.model, None, None))
            if allow_row_shard and t.shape[3] % policy.tp_size == 0:
                # heads don't divide the axis (qwen's 40H, gemma's 8H):
                # shard the q-chunk ROWS instead — each rank attends 1/tp
                # of the queries against full (replicated) KV, recovering
                # model-axis parallelism without touching the arch
                return jax.lax.with_sharding_constraint(
                    t, _P(None, policy.batch, None, policy.model, None))
            return jax.lax.with_sharding_constraint(
                t, _P(None, policy.batch, None, None, None))

        qs = pin(qs, allow_row_shard=True)
        ks, vs = pin(ks), pin(vs)

    pos_q = positions_q if positions_q is not None else jnp.arange(Sq)
    pos_k = positions_k if positions_k is not None else jnp.arange(Sk)
    if positions_q is not None and pad_q:
        pos_q = jnp.pad(pos_q, (0, pad_q))
    if positions_k is not None and pad_k:
        pos_k = jnp.pad(pos_k, (0, pad_k))

    def q_body(_, qi_and_idx):
        qi, iq = qi_and_idx

        # checkpoint: backward recomputes the [qc, kc] score block instead of
        # saving it — the whole point of flash-style chunking (otherwise the
        # scan's saved residuals reconstitute the full [S,S] matrix in HBM).
        @jax.checkpoint
        def kv_body(carry, kv_and_idx):
            o_acc, m_acc, l_acc = carry
            (ki, vi), ik = kv_and_idx
            vk = jax.lax.dynamic_slice_in_dim(kv_valid, ik * kv_chunk, kv_chunk)
            if causal:
                mq = jax.lax.dynamic_slice_in_dim(pos_q, iq * q_chunk, q_chunk)
                mk = jax.lax.dynamic_slice_in_dim(pos_k, ik * kv_chunk, kv_chunk)
                mask = (mq[:, None] >= mk[None, :]) & vk[None, :]
            elif pad_k:
                mask = jnp.broadcast_to(vk[None, :], (q_chunk, kv_chunk))
            else:
                mask = None
            o, m, l = _chunk_attend(qi, ki, vi, mask, scale)
            m_new = jnp.maximum(m_acc, m)
            c_old = jnp.exp(m_acc - m_new)
            c_new = jnp.exp(m - m_new)
            o_acc = o_acc * c_old[..., None] + o * c_new[..., None]
            l_acc = l_acc * c_old + l * c_new
            return (o_acc, m_new, l_acc), None

        o0 = jnp.zeros(qi.shape, jnp.float32)
        m0 = jnp.full(qi.shape[:-1], -1e30, jnp.float32)
        l0 = jnp.zeros(qi.shape[:-1], jnp.float32)
        (o, m, l), _ = jax.lax.scan(kv_body, (o0, m0, l0),
                                    ((ks, vs), jnp.arange(nk)))
        out = o / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_body, None, (qs, jnp.arange(nq)))  # [nq,B,H,qc,hd]
    out = outs.transpose(1, 2, 0, 3, 4).reshape(B, Hq, Sq, hd)
    return out.transpose(0, 2, 1, 3)[:, :Sq0]


# --------------------------------------------------------------------------
# GQA attention layer (params + apply for train/prefill/decode)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnDims:
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0


def attn_init(key, dims: AttnDims, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (dims.d_model, dims.n_heads, dims.d_head), (0,), dtype),
        "wk": dense_init(ks[1], (dims.d_model, dims.n_kv, dims.d_head), (0,), dtype),
        "wv": dense_init(ks[2], (dims.d_model, dims.n_kv, dims.d_head), (0,), dtype),
        "wo": dense_init(ks[3], (dims.n_heads, dims.d_head, dims.d_model), (0, 1), dtype),
    }
    if dims.qkv_bias:
        p["bq"] = jnp.zeros((dims.n_heads, dims.d_head), dtype)
        p["bk"] = jnp.zeros((dims.n_kv, dims.d_head), dtype)
        p["bv"] = jnp.zeros((dims.n_kv, dims.d_head), dtype)
    return p


def _qkv(p, x, dims: AttnDims, positions, *, use_rope=True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if dims.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if use_rope:
        q = rope(q, positions, theta=dims.rope_theta)
        k = rope(k, positions, theta=dims.rope_theta)
    return q, k, v


def replicate_kv(k, v, n_heads: int, n_kv: int, tp: int):
    """Replicate KV heads up to the TP degree when they don't divide it.

    With kv < tp-axis the kv heads can't shard; the in-chunk GQA repeat
    then produces UNSHARDED score blocks and XLA all-reduces them — tens
    of TB/step at 32k (§Perf iteration 2). Replicating kv→tp right after
    projection keeps the repeat shard-aligned (same layout blocks as the
    sharded q heads) at the standard cost of tp/kv× KV activation memory."""
    if tp and n_heads % tp == 0 and n_kv < tp and tp % n_kv == 0 and n_heads % tp == 0:
        r = tp // n_kv
        k = jnp.repeat(k, r, axis=2)
        v = jnp.repeat(v, r, axis=2)
    return k, v


def attn_apply(p, x, dims: AttnDims, *, causal=True, positions=None,
               q_chunk=512, kv_chunk=1024, use_rope=True, policy=None):
    """Training / prefill self-attention. x: [B, S, d]."""
    B, S, _ = x.shape
    tp = policy.tp_size if policy else 0
    pos = positions if positions is not None else jnp.arange(S)
    q, k, v = _qkv(p, x, dims, pos, use_rope=use_rope)
    k, v = replicate_kv(k, v, dims.n_heads, dims.n_kv, tp)
    o = chunked_attention(q, k, v, causal=causal, q_chunk=q_chunk, kv_chunk=kv_chunk,
                          positions_q=pos, positions_k=pos, policy=policy)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def cross_attn_apply(p, x, kv_cache_k, kv_cache_v, dims: AttnDims,
                     q_chunk=512, kv_chunk=1024, policy=None):
    """Cross attention to precomputed memory K/V: [B, S_kv, n_kv, hd]."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if dims.qkv_bias:
        q = q + p["bq"]
    o = chunked_attention(q, kv_cache_k, kv_cache_v, causal=False,
                          q_chunk=q_chunk, kv_chunk=kv_chunk, policy=policy)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def cross_kv(p, mem, dims: AttnDims):
    """Precompute cross-attention K/V from encoder/image memory [B, S, d]."""
    k = jnp.einsum("bsd,dhk->bshk", mem, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", mem, p["wv"])
    if dims.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return k, v


def attn_decode(p, x, cache_k, cache_v, cur_len, dims: AttnDims, *, use_rope=True):
    """Single-token decode. x:[B,1,d]; cache:[B,S_max,n_kv,hd] (may be
    sequence-sharded by the caller). Returns (out [B,1,d], new_k, new_v).

    The softmax runs over the full cache with positions >= cur_len masked —
    XLA partitions this cleanly when the cache is sharded on batch or heads;
    serving.py provides the shard_map flash-merge variant for seq-sharded
    caches (§Perf).
    """
    B = x.shape[0]
    pos = jnp.full((B, 1), cur_len, jnp.int32)
    q, k, v = _qkv(p, x, dims, pos, use_rope=use_rope)
    new_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), cur_len, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), cur_len, axis=1)
    groups = dims.n_heads // dims.n_kv
    kq = jnp.repeat(new_k, groups, axis=2)
    vq = jnp.repeat(new_v, groups, axis=2)
    s = jnp.einsum("bshk,bthk->bhst", q, kq.astype(q.dtype),
                   preferred_element_type=jnp.float32) / math.sqrt(dims.d_head)
    valid = (jnp.arange(cache_k.shape[1]) <= cur_len)[None, None, None, :]
    s = jnp.where(valid, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhst,bthk->bshk", w.astype(vq.dtype), vq,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), new_k, new_v


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------


def mlp_init(key, d_model, d_ff, *, gated=True, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d_model, d_ff), (0,), dtype),
         "w_down": dense_init(ks[1], (d_ff, d_model), (0,), dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), (0,), dtype)
    return p


def mlp_apply(p, x, *, act: str = "silu"):
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if "w_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = (jax.nn.gelu(g) if act == "gelu" else jax.nn.silu(g)) * up
    else:
        h = jax.nn.gelu(up) if act == "gelu" else jax.nn.silu(up)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])
