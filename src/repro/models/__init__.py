"""Assigned-architecture model zoo (pure JAX, scan-over-layers, pjit-sharded).

The paper's GP technique is orthogonal to these architectures (DESIGN.md
§5); they exercise the framework's distribution substrate and provide the
40 dry-run/roofline cells.
"""
from repro.models.model import build_model, input_specs, make_serve_step, make_train_step  # noqa: F401
