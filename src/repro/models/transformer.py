"""Generic decoder stack: every assigned architecture is a layer *pattern*.

A model is `n_groups` repetitions of a static pattern of blocks, e.g.

    dense LM     : [("attn", "dense")]                       × n_layers
    MoE LM       : [("attn", "moe")]                         × n_layers
    Mamba-2      : [("mamba", "none")]                       × n_layers
    Jamba (1:7)  : [(attn,dense), (mamba,moe), (mamba,dense), ...] × 9
    Whisper dec  : [("attn", "dense", cross=True)]           × 24
    Llama-Vision : [(cross,dense), (attn,dense) × 4]         × 20

Group parameters are stacked on a leading [n_groups] axis and the stack
runs under `lax.scan` with `jax.checkpoint` around the group body — the
compiled HLO is O(pattern), not O(n_layers), which keeps the 88-layer /
100-layer dry-runs compilable and gives the standard remat memory profile.

Sharding is expressed only through `with_sharding_constraint` on a few
canonical intermediates (residual stream, logits) plus the in_shardings
on the stacked params (launch/sharding.py); XLA SPMD propagates the rest.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S

# --------------------------------------------------------------------------
# sharding policy
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Mesh-axis names used in activation constraints. None = no constraints
    (single-device smoke tests)."""

    batch: tuple = ("data",)  # axes sharding the batch dim
    model: str = "model"  # tensor-parallel axis
    tp_size: int = 16  # size of the model axis (for divisibility rules)
    dp_size: int = 16  # product of batch-axis sizes (for divisibility rules)
    seq_shard_residual: bool = True  # Megatron-SP style residual layout
    seq_axis_for_cache: str | None = None  # context-parallel KV/long-context

    def __hash__(self):
        return hash((self.batch, self.model, self.tp_size, self.dp_size,
                     self.seq_shard_residual, self.seq_axis_for_cache))


def _shard(x, cfg, spec):
    if getattr(cfg, "policy", None) is None:
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _residual_spec(cfg, seq_sharded: bool):
    pol = cfg.policy
    if seq_sharded and pol.seq_shard_residual:
        return (pol.batch, pol.model, None)
    return (pol.batch, None, None)


# --------------------------------------------------------------------------
# block init / apply
# --------------------------------------------------------------------------


def _norm_init(cfg, dtype):
    if cfg.norm == "ln":
        return {"scale": jnp.ones((cfg.d_model,), dtype),
                "bias": jnp.zeros((cfg.d_model,), dtype)}
    init = jnp.zeros if cfg.norm_plus_one else jnp.ones
    return {"scale": init((cfg.d_model,), dtype)}


def _apply_norm(cfg, p, x):
    if cfg.norm == "ln":
        return L.layer_norm(x, p["scale"], p["bias"])
    return L.rms_norm(x, p["scale"], plus_one=cfg.norm_plus_one)


def block_init(cfg, key, mixer: str, mlp_kind: str, dtype):
    k1, k2 = jax.random.split(key)
    p: dict[str, Any] = {"norm1": _norm_init(cfg, dtype)}
    if mixer in ("attn", "attn_full", "cross"):
        p["attn"] = L.attn_init(k1, cfg.attn_dims, dtype)
    elif mixer == "mamba":
        p["ssm"] = S.ssm_init(k1, cfg.ssm_dims, dtype)
    else:
        raise ValueError(mixer)
    if mlp_kind == "dense":
        p["norm2"] = _norm_init(cfg, dtype)
        p["mlp"] = L.mlp_init(k2, cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp, dtype=dtype)
    elif mlp_kind == "moe":
        p["norm2"] = _norm_init(cfg, dtype)
        p["mlp"] = M.moe_init(k2, cfg.d_model, cfg.moe_d_ff, cfg.moe_experts,
                              gated=cfg.gated_mlp, dtype=dtype)
    elif mlp_kind != "none":
        raise ValueError(mlp_kind)
    return p


def _apply_mlp(cfg, p, x, mlp_kind: str):
    if mlp_kind == "none":
        return x, 0.0
    h = _apply_norm(cfg, p["norm2"], x)
    if mlp_kind == "dense":
        return x + L.mlp_apply(p["mlp"], h, act=cfg.act), 0.0
    if M.sharded_path_ok(cfg.policy, h.shape, cfg.moe_experts):
        # own remat boundary: without it the group-scan saves the shard_map
        # internals (expert hiddens) as backward residuals — one [C,ff]
        # buffer per MoE layer network-wide
        moe_fn = jax.checkpoint(
            lambda pp, hh: M.moe_apply_sharded(
                pp, hh, top_k=cfg.moe_top_k, act=cfg.act,
                capacity_factor=cfg.moe_capacity_factor, policy=cfg.policy))
        y, aux = moe_fn(p["mlp"], h)
    else:
        y, aux = M.moe_apply(p["mlp"], h, top_k=cfg.moe_top_k, act=cfg.act,
                             capacity_factor=cfg.moe_capacity_factor)
    return x + y, aux


def block_apply_train(cfg, p, x, mixer: str, mlp_kind: str, memory=None, causal=True):
    """x: [B,S,d]; memory: [B,M,d] for cross blocks. Returns (x, aux_loss)."""
    h = _apply_norm(cfg, p["norm1"], x)
    if mixer in ("attn", "attn_full"):
        h = _shard(h, cfg, (cfg.policy.batch, None, None)) if cfg.policy else h
        o = L.attn_apply(p["attn"], h, cfg.attn_dims, causal=(mixer == "attn") and causal,
                         q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk, policy=cfg.policy)
        x = x + o
    elif mixer == "cross":
        ck, cv = L.cross_kv(p["attn"], memory, cfg.attn_dims)
        x = x + L.cross_attn_apply(p["attn"], h, ck, cv, cfg.attn_dims,
                                   q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                   policy=cfg.policy)
    elif mixer == "mamba":
        o, _, _ = S.ssm_apply(p["ssm"], h, cfg.ssm_dims, policy=cfg.policy)
        x = x + o
    x, aux = _apply_mlp(cfg, p, x, mlp_kind)
    if cfg.policy:
        x = _shard(x, cfg, _residual_spec(cfg, seq_sharded=True))
    return x, aux


def block_cache_init(cfg, mixer: str, batch: int, max_len: int, dtype):
    d = cfg.attn_dims
    if mixer in ("attn", "attn_full"):
        shp = (batch, max_len, d.n_kv, d.d_head)
        return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
    if mixer == "cross":
        shp = (batch, cfg.n_memory, d.n_kv, d.d_head)
        return {"ck": jnp.zeros(shp, dtype), "cv": jnp.zeros(shp, dtype)}
    if mixer == "mamba":
        sd = cfg.ssm_dims
        return {"ssm": jnp.zeros((batch, sd.n_heads, sd.d_state, sd.headdim), jnp.float32),
                "conv": jnp.zeros((batch, sd.d_conv - 1, sd.conv_dim), dtype)}
    raise ValueError(mixer)


def block_apply_decode(cfg, p, x, cache, cur_len, mixer: str, mlp_kind: str):
    """x: [B,1,d]. Returns (x, new_cache)."""
    h = _apply_norm(cfg, p["norm1"], x)
    if mixer in ("attn", "attn_full"):
        o, nk, nv = L.attn_decode(p["attn"], h, cache["k"], cache["v"], cur_len,
                                  cfg.attn_dims)
        x, cache = x + o, {"k": nk, "v": nv}
    elif mixer == "cross":
        x = x + L.cross_attn_apply(p["attn"], h, cache["ck"], cache["cv"], cfg.attn_dims,
                                   q_chunk=1, kv_chunk=cfg.kv_chunk)
    elif mixer == "mamba":
        o, ns, nc = S.ssm_decode(p["ssm"], h, cache["ssm"], cache["conv"], cfg.ssm_dims)
        x, cache = x + o, {"ssm": ns, "conv": nc}
    x, _ = _apply_mlp(cfg, p, x, mlp_kind)
    return x, cache


# --------------------------------------------------------------------------
# stack init / apply (scan over groups)
# --------------------------------------------------------------------------


def stack_init(cfg, key, pattern, n_groups: int, dtype):
    def one_group(k):
        ks = jax.random.split(k, len(pattern))
        return {f"b{i}": block_init(cfg, ks[i], mx, ml, dtype)
                for i, (mx, ml) in enumerate(pattern)}

    return jax.vmap(one_group)(jax.random.split(key, n_groups))


def stack_apply_train(cfg, gparams, x, pattern, memory=None, causal=True):
    def group_body(carry, gp):
        h, aux = carry
        for i, (mx, ml) in enumerate(pattern):
            h, a = block_apply_train(cfg, gp[f"b{i}"], h, mx, ml, memory, causal)
            aux = aux + a
        return (h, aux), None

    body = jax.checkpoint(group_body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, 0.0), gparams)
    return x, aux


def block_apply_prefill(cfg, p, x, mixer: str, mlp_kind: str, max_len: int,
                        cache_dtype, memory=None):
    """Train-path compute + cache construction. x: [B,S,d] → (x, cache)."""
    B, Sq, _ = x.shape
    d = cfg.attn_dims
    h = _apply_norm(cfg, p["norm1"], x)
    if mixer in ("attn", "attn_full"):
        pos = jnp.arange(Sq)
        q, k, v = L._qkv(p["attn"], h, d, pos)
        kr, vr = L.replicate_kv(k, v, d.n_heads, d.n_kv,
                                cfg.policy.tp_size if cfg.policy else 0)
        o = L.chunked_attention(q, kr, vr, causal=(mixer == "attn"),
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                policy=cfg.policy)
        x = x + jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
        pad = max_len - Sq
        cache = {"k": jnp.pad(k.astype(cache_dtype), ((0, 0), (0, pad), (0, 0), (0, 0))),
                 "v": jnp.pad(v.astype(cache_dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))}
    elif mixer == "cross":
        ck, cv = L.cross_kv(p["attn"], memory, d)
        x = x + L.cross_attn_apply(p["attn"], h, ck, cv, d,
                                   q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                   policy=cfg.policy)
        cache = {"ck": ck.astype(cache_dtype), "cv": cv.astype(cache_dtype)}
    elif mixer == "mamba":
        o, final, conv_tail = S.ssm_apply(p["ssm"], h, cfg.ssm_dims, policy=cfg.policy)
        x = x + o
        cache = {"ssm": final, "conv": conv_tail.astype(cache_dtype)}
    else:
        raise ValueError(mixer)
    x, _ = _apply_mlp(cfg, p, x, mlp_kind)
    if cfg.policy:
        x = _shard(x, cfg, _residual_spec(cfg, seq_sharded=True))
    return x, cache


def stack_apply_prefill(cfg, gparams, x, pattern, max_len, cache_dtype, memory=None):
    def group_body(h, gp):
        caches = {}
        for i, (mx, ml) in enumerate(pattern):
            h, caches[f"b{i}"] = block_apply_prefill(cfg, gp[f"b{i}"], h, mx, ml,
                                                     max_len, cache_dtype, memory)
        return h, caches

    x, cache = jax.lax.scan(group_body, x, gparams)
    return x, cache


def stack_cache_init(cfg, pattern, n_groups, batch, max_len, dtype):
    def one(mx):
        c = block_cache_init(cfg, mx, batch, max_len, dtype)
        return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n_groups,) + a.shape), c)

    return {f"b{i}": one(mx) for i, (mx, ml) in enumerate(pattern)}


def stack_apply_decode(cfg, gparams, x, cache, cur_len, pattern):
    def group_body(h, scans):
        gp, gc = scans
        new_c = {}
        for i, (mx, ml) in enumerate(pattern):
            h, new_c[f"b{i}"] = block_apply_decode(cfg, gp[f"b{i}"], h, gc[f"b{i}"],
                                                   cur_len, mx, ml)
        return h, new_c

    x, new_cache = jax.lax.scan(group_body, x, (gparams, cache))
    return x, new_cache


# --------------------------------------------------------------------------
# embeddings + loss
# --------------------------------------------------------------------------


def embed_init(cfg, key, dtype):
    e = {"embed": L.dense_init(key, (cfg.vocab, cfg.d_model), (1,), dtype)}
    if not cfg.tie_embeddings:
        e["unembed"] = L.dense_init(jax.random.fold_in(key, 1),
                                    (cfg.d_model, cfg.vocab), (0,), dtype)
    return e


def embed_tokens(cfg, params, tokens):
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _unembed_matrix(cfg, params):
    return params["embed"].T if cfg.tie_embeddings else params["unembed"]


def chunked_ce_loss(cfg, params, x, labels, mask, *, chunk: int = 512):
    """Cross-entropy without a [B,S,V] resident: scan over seq chunks with
    the logits' vocab dim sharding-constrained to the model axis."""
    B, Sq, d = x.shape
    W = _unembed_matrix(cfg, params)
    chunk = min(chunk, Sq)
    assert Sq % chunk == 0
    n = Sq // chunk
    xs = (x.reshape(B, n, chunk, d).transpose(1, 0, 2, 3),
          labels.reshape(B, n, chunk).transpose(1, 0, 2),
          mask.reshape(B, n, chunk).transpose(1, 0, 2))

    # checkpoint: recompute the [B, chunk, V] logits block in backward rather
    # than saving one per scan step (which would re-materialize full logits).
    @jax.checkpoint
    def body(acc, inp):
        xc, yc, mc = inp
        logits = jnp.einsum("bsd,dv->bsv", xc, W,
                            preferred_element_type=jnp.float32)
        if cfg.policy:
            logits = _shard(logits, cfg, (cfg.policy.batch, None, cfg.policy.model))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return (acc[0] + nll.sum(), acc[1] + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),) * 2, xs)
    return tot / jnp.maximum(cnt, 1.0)


def logits_last(cfg, params, x_last):
    """x_last: [B, 1, d] → [B, 1, V] (decode head)."""
    logits = jnp.einsum("bsd,dv->bsv", x_last, _unembed_matrix(cfg, params),
                        preferred_element_type=jnp.float32)
    if cfg.policy:
        logits = _shard(logits, cfg, (cfg.policy.batch, None, cfg.policy.model))
    return logits
