"""Elastic scaling: reshard a restored train state onto a different mesh.

Checkpoints store whole (host-gathered) leaves, so restarting on a mesh
with a different device count is just a re-placement: compute the sharding
rules for the NEW mesh and `device_put` each leaf. Divisibility fallbacks
in launch/sharding.py mean the same rules produce legal layouts at any
axis size — the property test in tests/test_ckpt.py restores a state saved
from a (2,2) mesh onto (4,1) and (1,2) meshes and checks bit-equality.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding

from repro.launch import sharding as SH


def reshard_state(state_host, cfg, mesh):
    """Host-side train state → device arrays sharded for `mesh`."""
    cfg = cfg.with_policy(cfg.policy) if cfg.policy else cfg
    shapes = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state_host)
    specs = SH.train_state_specs(cfg, shapes, mesh)
    return jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)), state_host, specs)


def reshard_tree(tree_host, spec_tree, mesh):
    return jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)), tree_host, spec_tree)
