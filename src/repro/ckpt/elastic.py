"""Elastic scaling: reshard a restored train state onto a different mesh.

Checkpoints store whole (host-gathered) leaves, so restarting on a mesh
with a different device count is just a re-placement: compute the sharding
rules for the NEW mesh and `device_put` each leaf. Divisibility fallbacks
in launch/sharding.py mean the same rules produce legal layouts at any
axis size — the property test in tests/test_ckpt.py restores a state saved
from a (2,2) mesh onto (4,1) and (1,2) meshes and checks bit-equality.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding

from repro.launch import sharding as SH


def reshard_state(state_host, cfg, mesh):
    """Host-side train state → device arrays sharded for `mesh`."""
    cfg = cfg.with_policy(cfg.policy) if cfg.policy else cfg
    shapes = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state_host)
    specs = SH.train_state_specs(cfg, shapes, mesh)
    return jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)), state_host, specs)


def reshard_tree(tree_host, spec_tree, mesh):
    return jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)), tree_host, spec_tree)


def gp_state_specs(cfg, mesh, *, data_axis="data", model_axis="model",
                   pod_axis=None):
    """PartitionSpecs of a GPState on `mesh` — exactly the specs the
    engine's sharded step was built with (the same builder produces
    both), so a resharded state lands where `sharded_evolve_step`/`_block`
    expects it. Layout follows cfg.island.islands: classic (population on
    (pod, model)) or island-batched (island axis on pod, population on
    model)."""
    from repro.core import engine

    _, state_specs, *_ = engine._pick_step_builder(cfg)(
        cfg, mesh, data_axis=data_axis, model_axis=model_axis,
        pod_axis=pod_axis)
    return state_specs


def reshard_gp_state(state_host, cfg, mesh, *, data_axis="data",
                     model_axis="model", pod_axis=None):
    """Host-side GPState (a restored checkpoint) → device arrays sharded
    for `mesh` — the GP run's elastic-scaling path: a state saved from an
    `islands=I` run on one pod/device count resumes on another, as long
    as the new mesh's axes still divide the layout (islands % pod == 0,
    pop_size % model == 0; the engine builder validates). Whole-leaf
    checkpoints make this pure re-placement, bit-identical by
    construction."""
    return reshard_tree(state_host, gp_state_specs(
        cfg, mesh, data_axis=data_axis, model_axis=model_axis,
        pod_axis=pod_axis), mesh)
