"""Fault-tolerant checkpointing + elastic resharding."""
from repro.ckpt.checkpoint import CheckpointManager, restore, save  # noqa: F401
from repro.ckpt.elastic import reshard_state  # noqa: F401
