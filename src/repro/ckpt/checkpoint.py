"""Async, integrity-checked, retention-managed checkpointing.

Layout (one directory per step):

    <dir>/step_00000420/
        manifest.json   tree structure, shapes/dtypes, per-leaf sha256,
                        commit marker written LAST (torn-write detection)
        000000.npy ...  one file per leaf (host-gathered)

Design points for the 1000+-node posture (documented vs. simulated here):
  * save is ASYNC — the train loop donates a snapshot (device_get) and a
    background thread does the IO; step time sees only the host copy.
  * the manifest is written after all leaves fsync — a crashed save can
    never be mistaken for a valid checkpoint; `latest_step` only returns
    committed steps.
  * restore verifies sha256 per leaf before handing anything back.
  * on a real cluster each process writes its addressable shards
    (process-local files, same manifest scheme keyed by shard index);
    this repo runs single-process so leaves are saved whole. The elastic
    path (ckpt/elastic.py) reshards whole-leaf checkpoints onto any mesh,
    which is what lets a job restart with a different device count.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _tree_paths(tree):
    return [jax.tree_util.keystr(p)
            for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]


def save(tree, directory: str, step: int) -> str:
    """Synchronous save. Returns the checkpoint path."""
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(jax.device_get(tree))
    manifest = {"step": step, "treedef": str(treedef), "time": time.time(),
                "paths": _tree_paths(tree), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        fname = f"{i:06d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        digest = hashlib.sha256(arr.tobytes()).hexdigest()
        manifest["leaves"].append({"file": fname, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype), "sha256": digest})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)  # atomic commit
    return path


def restore(directory: str, step: int, like=None, *, verify: bool = True):
    """Load a checkpoint; verify digests; optionally restructure to `like`
    (a pytree prototype whose treedef the leaves are unflattened into)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = []
    for meta in manifest["leaves"]:
        arr = np.load(os.path.join(path, meta["file"]))
        if verify:
            digest = hashlib.sha256(arr.tobytes()).hexdigest()
            if digest != meta["sha256"]:
                raise IOError(f"checkpoint corruption in {path}/{meta['file']}")
        leaves.append(arr)
    if like is not None:
        treedef = jax.tree_util.tree_structure(like)
        if treedef.num_leaves != len(leaves):
            raise ValueError(
                f"checkpoint at {path} has {len(leaves)} leaves but the "
                f"restore target expects {treedef.num_leaves} — the state "
                f"format changed between writer and reader (e.g. a "
                f"pre-elite-cache GPState); restore with like=None and "
                f"migrate the leaves, or re-initialize")
        return jax.tree_util.tree_unflatten(treedef, leaves)
    return leaves, manifest


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(directory, d, "manifest.json"))]
    return max(steps) if steps else None


class CheckpointManager:
    """Async save + retention. One background IO thread; `wait()` joins."""

    def __init__(self, directory: str, *, keep: int = 3, every: int = 100):
        self.directory = directory
        self.keep = keep
        self.every = every
        self._thread: threading.Thread | None = None
        self.saved_steps: list[int] = []

    def maybe_save(self, tree, step: int, *, force: bool = False):
        if not force and (step == 0 or step % self.every):
            return False
        snapshot = jax.device_get(tree)  # block only for D2H, not IO
        self.wait()
        self._thread = threading.Thread(target=self._save, args=(snapshot, step),
                                        daemon=True)
        self._thread.start()
        return True

    def _save(self, snapshot, step: int):
        save(snapshot, self.directory, step)
        self.saved_steps.append(step)
        self._retain()

    def _retain(self):
        steps = sorted({int(d.split("_")[1]) for d in os.listdir(self.directory)
                        if d.startswith("step_") and not d.endswith(".tmp")})
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, like):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return restore(self.directory, step, like=like), step
