"""qwen1.5-32b [dense] — 64L d_model=5120 40H (GQA kv=40) d_ff=27392
vocab=152064, QKV bias. [hf:Qwen/Qwen1.5-0.5B family; hf]"""
import dataclasses

from repro.models.model import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv=40, d_head=128,
    d_ff=27392, vocab=152064, act="silu", qkv_bias=True,
    rope_theta=1_000_000.0,
    accum_steps=2,
    # MHA (kv=40) at 128×32k decode is a 5.5 TB cache in bf16 — 21.5 GB/chip
    # even sharded both ways. fp8 KV (vLLM-style) halves it under budget.
    cache_dtype="float8_e4m3fn",
    pattern=(("attn", "dense"),),
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, accum_steps=1, n_layers=2, d_model=64, n_heads=4, n_kv=4, d_head=16,
        d_ff=128, vocab=256, q_chunk=16, kv_chunk=16)
