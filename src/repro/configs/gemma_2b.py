"""gemma-2b [dense] — 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=256000; GeGLU, head_dim=256, RMSNorm(1+scale), scaled+tied embeddings.
[arXiv:2403.08295; hf]"""
import dataclasses

from repro.models.model import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv=1, d_head=256,
    d_ff=16384, vocab=256000, act="gelu", gated_mlp=True,
    norm_plus_one=True, embed_scale=True, tie_embeddings=True,
    pattern=(("attn", "dense"),),
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, accum_steps=1, n_layers=2, d_model=64, n_heads=4, n_kv=1, d_head=16,
        d_ff=128, vocab=256, q_chunk=16, kv_chunk=16)
