"""mistral-large-123b [dense] — 88L d_model=12288 96H (GQA kv=8)
d_ff=28672 vocab=32768. [hf:mistralai/Mistral-Large-Instruct-2407;
unverified]"""
import dataclasses

from repro.models.model import ArchConfig

CONFIG = ArchConfig(
    name="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv=8, d_head=128,
    d_ff=28672, vocab=32768, act="silu", rope_theta=1_000_000.0,
    accum_steps=8,
    pattern=(("attn", "dense"),),
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, accum_steps=1, n_layers=2, d_model=64, n_heads=8, n_kv=2, d_head=8,
        d_ff=128, vocab=256, q_chunk=16, kv_chunk=16)
