"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4, head_dim=128)
expert d_ff=768 vocab=151936, MoE 128 experts top-8.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
import dataclasses

from repro.models.model import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv=4, d_head=128,
    d_ff=768, vocab=151936, act="silu", rope_theta=1_000_000.0,
    moe_experts=128, moe_top_k=8, moe_d_ff=768,
    accum_steps=4,
    pattern=(("attn", "moe"),),
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, accum_steps=1, n_layers=2, d_model=64, n_heads=4, n_kv=2, d_head=16,
        d_ff=64, vocab=256, moe_experts=8, moe_top_k=2, moe_d_ff=64,
        q_chunk=16, kv_chunk=16)
