"""mamba2-370m [ssm] — 48L d_model=1024 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality). Sub-quadratic: runs long_500k.
[arXiv:2405.21060; unverified]"""
import dataclasses

from repro.models.model import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv=0, d_head=0,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_headdim=64, ssm_groups=1, ssm_chunk=128,
    tie_embeddings=True, subquadratic=True,
    pattern=(("mamba", "none"),),
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, accum_steps=1, n_layers=2, d_model=64, vocab=256, ssm_state=16,
        ssm_headdim=16, ssm_chunk=8)
