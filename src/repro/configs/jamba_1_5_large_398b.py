"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2; Mamba:attn 7:1 interleave.

Structure: 9 groups of 8 blocks — [attn, mamba×7], MoE MLP on every other
block (4 MoE per group → 36 MoE layers). Jamba-1.5 ships Mamba-1 mixers;
we substitute the SSD (Mamba-2) block as the TPU-native equivalent
(DESIGN.md §7). Adafactor: AdamW moments would exceed the single-pod HBM
budget at 398B params. Sub-quadratic (9/72 attention layers): runs
long_500k. [arXiv:2403.19887; hf]"""
import dataclasses

from repro.models.model import ArchConfig

_GROUP = (
    ("attn", "dense"), ("mamba", "moe"), ("mamba", "dense"), ("mamba", "moe"),
    ("mamba", "dense"), ("mamba", "moe"), ("mamba", "dense"), ("mamba", "moe"),
)

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv=8, d_head=128,
    d_ff=24576, vocab=65536, act="silu",
    moe_experts=16, moe_top_k=2, moe_d_ff=24576,
    ssm_state=128, ssm_headdim=64, ssm_groups=8, ssm_chunk=128,
    optimizer="adafactor", subquadratic=True,
    accum_steps=4,
    moe_capacity_factor=1.0,
    pattern=_GROUP,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, accum_steps=1, n_layers=8, d_model=64, n_heads=4, n_kv=2, d_head=16,
        d_ff=128, vocab=256, moe_experts=4, moe_top_k=2, moe_d_ff=128,
        ssm_state=16, ssm_headdim=16, ssm_groups=2, ssm_chunk=8,
        q_chunk=16, kv_chunk=16)
