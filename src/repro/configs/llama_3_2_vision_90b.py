"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256; 80 self-attention + 20 cross-attention image
layers (every 5th block). Vision tower STUBBED: input_specs() provides
precomputed patch embeddings (n_memory=1600).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
import dataclasses

from repro.models.model import ArchConfig

_GROUP = (("cross", "dense"), ("attn", "dense"), ("attn", "dense"),
          ("attn", "dense"), ("attn", "dense"))

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv=8, d_head=128,
    d_ff=28672, vocab=128256, act="silu", rope_theta=500_000.0,
    n_memory=1600,
    accum_steps=4,
    pattern=_GROUP,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, accum_steps=1, n_layers=10, d_model=64, n_heads=4, n_kv=2, d_head=16,
        d_ff=128, vocab=256, n_memory=16, q_chunk=16, kv_chunk=16)
