"""minitron-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000; width/depth-pruned Nemotron-4. [arXiv:2407.14679; hf]"""
import dataclasses

from repro.models.model import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_head=128,
    d_ff=16384, vocab=256000, act="silu",
    accum_steps=2,
    pattern=(("attn", "dense"),),
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, accum_steps=1, n_layers=2, d_model=64, n_heads=4, n_kv=2, d_head=16,
        d_ff=128, vocab=256, q_chunk=16, kv_chunk=16)
