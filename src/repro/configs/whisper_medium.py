"""whisper-medium [audio] — enc-dec, 24L(+24L enc) d_model=1024 16H
d_ff=4096 vocab=51865; LayerNorm, GELU (ungated), sinusoidal positions,
conv frontend STUBBED: input_specs() feeds precomputed frame embeddings
(n_memory=1500 ≙ 30 s of audio at 50 Hz). [arXiv:2212.04356; unverified]"""
import dataclasses

from repro.models.model import ArchConfig

# One whisper decoder layer = self-attn -> cross-attn -> MLP; expressed as
# two blocks per layer, so n_layers=48 blocks ≙ 24 decoder layers.
CONFIG = ArchConfig(
    name="whisper-medium", family="encdec",
    n_layers=48, d_model=1024, n_heads=16, n_kv=16, d_head=64,
    d_ff=4096, vocab=51865, act="gelu", gated_mlp=False,
    norm="ln", pos_embed="sinusoidal",
    enc_layers=24, n_memory=1500,
    pattern=(("attn", "none"), ("cross", "dense")),
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, accum_steps=1, n_layers=4, d_model=64, n_heads=4, n_kv=4, d_head=16,
        d_ff=128, vocab=256, enc_layers=2, n_memory=16,
        q_chunk=16, kv_chunk=16)
