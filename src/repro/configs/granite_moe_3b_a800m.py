"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8)
expert d_ff=512 vocab=49155, MoE 40 experts top-8.

Note: the assigned spec says 40e; the cited hf card
(ibm-granite/granite-3.0-1b-a400m-base) is a 32e sibling — we follow the
assigned 40e (DESIGN.md §5). 40 experts do not divide the 16-way model
axis, so EP falls back to sharding the per-expert ff dim
(launch/sharding.py). [hf; assigned spec]"""
import dataclasses

from repro.models.model import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv=8, d_head=64,
    d_ff=512, vocab=49155, act="silu",
    moe_experts=40, moe_top_k=8, moe_d_ff=512,
    accum_steps=4,
    tie_embeddings=True,
    pattern=(("attn", "moe"),),
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, accum_steps=1, n_layers=2, d_model=64, n_heads=4, n_kv=2, d_head=16,
        d_ff=64, vocab=256, moe_experts=5, moe_top_k=2, moe_d_ff=64,
        q_chunk=16, kv_chunk=16)
