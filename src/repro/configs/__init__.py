"""Config registry: one module per assigned architecture (+ the paper's own
GP workload configs in karoo.py). `get_config(name)` returns the exact
published config; `get_reduced(name)` returns the same family scaled down
for CPU smoke tests."""
from __future__ import annotations

import importlib

ARCHS = (
    "qwen1_5_32b",
    "gemma_2b",
    "mistral_large_123b",
    "minitron_8b",
    "granite_moe_3b_a800m",
    "qwen3_moe_30b_a3b",
    "whisper_medium",
    "mamba2_370m",
    "jamba_1_5_large_398b",
    "llama_3_2_vision_90b",
)

# canonical ids (as assigned) → module names
IDS = {
    "qwen1.5-32b": "qwen1_5_32b",
    "gemma-2b": "gemma_2b",
    "mistral-large-123b": "mistral_large_123b",
    "minitron-8b": "minitron_8b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "whisper-medium": "whisper_medium",
    "mamba2-370m": "mamba2_370m",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
}


def _module(name: str):
    mod = IDS.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str):
    return _module(name).CONFIG


def get_reduced(name: str):
    return _module(name).reduced()


def all_arch_names():
    return list(IDS)
