"""Packing heterogeneous jobs into the engine's island layout.

The batch shape is fixed at service construction — `[I, P, N]` state,
`[I, F_cap, D_cap]` data — and EVERYTHING job-specific is an operand:
per-slot data buffers (a job's rows zero-weight padded to `D_cap`, its
feature columns zero-padded to `F_cap` — the same `weight` mask contract
every fitness kernel already honours for dataset padding) and the traced
`TenantParams` table. So packing a new job into a free slot is a row
write, not a recompile, and ragged datasets share one compiled program.

`JobBatch` owns the slot assignment plus the host-side mirrors of those
operands; the scheduler admits/evicts through it and asks for the device
operands per dispatch (rebuilt only when a slot actually changed)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.engine import TenantParams
from repro.service.job import JobHandle, JobSpec

# a disabled early stop: best_fitness <= -inf is never true
NO_STOP = -np.inf


def slot_buffers(spec: JobSpec, n_features: int, data_cap: int):
    """One job's padded slot data: (X f32[F_cap, D_cap] feature-major,
    y f32[D_cap], w f32[D_cap]). Padded rows carry weight 0.0 (excluded
    from fitness exactly), padded feature columns are all-zero (the
    tree's terminal set may reference them; they read as the constant
    0)."""
    D, F = spec.X.shape
    if D > data_cap:
        raise ValueError(f"job {spec.name!r} has {D} rows > data_cap {data_cap}")
    if F > n_features:
        raise ValueError(f"job {spec.name!r} has {F} features > n_features "
                         f"{n_features}")
    X = np.zeros((n_features, data_cap), np.float32)
    X[:F, :D] = np.ascontiguousarray(spec.X.T)
    y = np.zeros((data_cap,), np.float32)
    y[:D] = spec.y
    w = np.zeros((data_cap,), np.float32)
    w[:D] = 1.0
    return X, y, w


def pack_order(pending: list[JobHandle], n_free: int,
               strategy: str = "fifo") -> list[JobHandle]:
    """Which pending jobs claim the free slots this boundary.

    fifo  submit order — deterministic, starvation-free; the default.
    lpt   longest-processing-time first: largest REMAINING generation
          budget admitted first (classic makespan heuristic for packing
          unequal jobs onto identical machines); submit order breaks
          ties so equal-budget jobs keep FIFO fairness.
    """
    if strategy == "fifo":
        ranked = pending
    elif strategy == "lpt":
        ranked = sorted(pending, key=lambda h: (-(h.spec.generations
                                                  - h.gens_done), h.job_id))
    else:
        raise ValueError(f"unknown packing strategy {strategy!r}; "
                         f"use 'fifo' or 'lpt'")
    return list(ranked[:n_free])


class JobBatch:
    """Slot assignment + host mirrors of the per-slot operands.

    `slots[i]` is the JobHandle occupying island slot `i` (None = empty).
    Data and parameter rows are written on admit/evict; `operands()`
    returns the device-ready (X, y, w, TenantParams) tuple, re-uploading
    only after a slot changed. Empty slots get a zero dataset, zero
    weights and a 0 generation budget — `tenant_active` freezes them, so
    their compute is discarded on device."""

    def __init__(self, islands: int, n_features: int, data_cap: int,
                 kernels: tuple, tourn_draw: int):
        self.islands = islands
        self.n_features = n_features
        self.data_cap = data_cap
        self.kernels = kernels
        self.tourn_draw = tourn_draw
        self.slots: list[JobHandle | None] = [None] * islands
        I = islands
        self._X = np.zeros((I, n_features, data_cap), np.float32)
        self._y = np.zeros((I, data_cap), np.float32)
        self._w = np.zeros((I, data_cap), np.float32)
        self._probs = np.tile(np.asarray([0.1, 0.1, 0.1, 0.7], np.float32),
                              (I, 1))
        self._tourn = np.full((I,), tourn_draw, np.int32)
        self._point_rate = np.full((I,), 0.25, np.float32)
        self._kernel_id = np.zeros((I,), np.int32)
        self._n_classes = np.full((I,), 2.0, np.float32)
        self._precision = np.full((I,), 1e-4, np.float32)
        self._stop = np.full((I,), NO_STOP, np.float32)
        self._budget = np.zeros((I,), np.int32)
        self._dirty = True
        self._device = None  # cached (X, y, w, TenantParams) on device

    # --- queries --------------------------------------------------------------

    @property
    def free_slots(self) -> list[int]:
        return [i for i, h in enumerate(self.slots) if h is None]

    @property
    def occupied(self) -> list[tuple[int, JobHandle]]:
        return [(i, h) for i, h in enumerate(self.slots) if h is not None]

    def validate(self, spec: JobSpec):
        """Reject at submit time anything the fixed batch shape cannot
        hold — the service never recompiles to fit a job."""
        slot_buffers(spec, self.n_features, self.data_cap)  # shape check
        if spec.kernel not in self.kernels:
            raise ValueError(f"job kernel {spec.kernel!r} is not in the "
                             f"service's compiled kernel set {self.kernels}")
        if spec.tourn_size > self.tourn_draw:
            raise ValueError(f"job tourn_size {spec.tourn_size} exceeds the "
                             f"service's tournament draw {self.tourn_draw}")

    # --- mutation -------------------------------------------------------------

    def admit(self, slot: int, handle: JobHandle):
        assert self.slots[slot] is None, f"slot {slot} is occupied"
        spec = handle.spec
        self.validate(spec)
        X, y, w = slot_buffers(spec, self.n_features, self.data_cap)
        self._X[slot], self._y[slot], self._w[slot] = X, y, w
        self._probs[slot] = spec.mix.probs()
        self._tourn[slot] = spec.tourn_size
        self._point_rate[slot] = spec.point_rate
        self._kernel_id[slot] = self.kernels.index(spec.kernel)
        self._n_classes[slot] = float(spec.n_classes)
        self._precision[slot] = float(spec.precision)
        self._stop[slot] = (NO_STOP if spec.stop_fitness is None
                            else float(spec.stop_fitness))
        self._budget[slot] = int(spec.generations)
        self.slots[slot] = handle
        handle._slot = slot
        self._dirty = True

    def evict(self, slot: int) -> JobHandle:
        handle = self.slots[slot]
        assert handle is not None, f"slot {slot} is empty"
        self.slots[slot] = None
        handle._slot = None
        # budget 0 freezes the slot; data can stay (compute is discarded)
        self._budget[slot] = 0
        self._stop[slot] = NO_STOP
        self._dirty = True
        return handle

    # --- operands -------------------------------------------------------------

    def params_host(self) -> TenantParams:
        """The host-side TenantParams table (checkpoint payload)."""
        return TenantParams(
            probs=self._probs.copy(), tourn=self._tourn.copy(),
            point_rate=self._point_rate.copy(),
            kernel_id=self._kernel_id.copy(),
            n_classes=self._n_classes.copy(),
            precision=self._precision.copy(), stop=self._stop.copy(),
            budget=self._budget.copy())

    def restore_params(self, params: TenantParams):
        """Overwrite the parameter table from a checkpoint (the data
        buffers are rebuilt by re-admitting the slotted jobs — they are
        derivable from the JobSpecs and never checkpointed)."""
        (self._probs, self._tourn, self._point_rate, self._kernel_id,
         self._n_classes, self._precision, self._stop, self._budget) = (
            np.asarray(leaf).copy() for leaf in params)
        self._dirty = True

    def operands(self):
        """(X, y, w, TenantParams) as device arrays — the tenant block's
        traced operands; uploaded only when a slot changed since the
        last call."""
        if self._dirty or self._device is None:
            self._device = (
                jnp.asarray(self._X), jnp.asarray(self._y),
                jnp.asarray(self._w),
                TenantParams(
                    probs=jnp.asarray(self._probs),
                    tourn=jnp.asarray(self._tourn),
                    point_rate=jnp.asarray(self._point_rate),
                    kernel_id=jnp.asarray(self._kernel_id),
                    n_classes=jnp.asarray(self._n_classes),
                    precision=jnp.asarray(self._precision),
                    stop=jnp.asarray(self._stop),
                    budget=jnp.asarray(self._budget)))
            self._dirty = False
        return self._device
