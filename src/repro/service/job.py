"""Job surface of the GP service: what a tenant submits and what they
poll. A `JobSpec` is one user's GP run — dataset, fitness kernel, search
parameters, termination — i.e. exactly the per-island degrees of freedom
of the engine's multi-tenant batch (`core.engine.TenantParams` plus the
slot's data buffers), which is what makes a job an island: everything
job-specific is a traced operand of the one compiled block program.

`JobHandle` is the service-side record the submit/poll/result/cancel
API reads and the scheduler mutates at block boundaries. Handles are
plain host objects; nothing here touches a device.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import fitness as fit
from repro.core.evolve import OperatorMix

# job lifecycle: PENDING -> RUNNING -> DONE, with CANCELLED reachable
# from both live states (a running job is cancelled at the next block
# boundary, partial results published)
PENDING = "pending"
RUNNING = "running"
DONE = "done"
CANCELLED = "cancelled"


@dataclasses.dataclass
class JobSpec:
    """One tenant's GP run request.

    X is row-major [rows, features] (sklearn layout, like GPSession.fit);
    y is f32[rows] targets (class ids as floats for the 'c' kernel). The
    remaining fields mirror a solo GPConfig: `kernel` picks the fitness
    objective, `mix`/`tourn_size`/`point_rate` the search behaviour,
    `stop_fitness` (None = run the full budget) and `generations` the
    termination. `seed` derives the job's private PRNG stream — a packed
    job replays the same stream a solo `islands=1` session with
    `PRNGKey(seed)` would, which is what the parity tests pin."""

    X: np.ndarray
    y: np.ndarray
    kernel: str = "r"
    mix: OperatorMix = dataclasses.field(default_factory=OperatorMix)
    tourn_size: int = 10
    point_rate: float = 0.25
    stop_fitness: float | None = None
    generations: int = 30
    n_classes: int = 3
    precision: float = 1e-4
    seed: int = 0
    name: str = ""
    feature_names: tuple | None = None

    def __post_init__(self):
        self.X = np.asarray(self.X, np.float32)
        self.y = np.asarray(self.y, np.float32)
        if self.X.ndim != 2:
            raise ValueError(f"X must be [rows, features], got shape "
                             f"{self.X.shape}")
        if self.y.shape != (self.X.shape[0],):
            raise ValueError(f"y shape {self.y.shape} does not match "
                             f"{self.X.shape[0]} rows")
        if self.generations < 1:
            raise ValueError("generations must be >= 1")
        if self.tourn_size < 1:
            raise ValueError("tourn_size must be >= 1")
        # canonicalize the kernel name now so packing compares apples
        self.kernel = fit.get_kernel(self.kernel).name

    @property
    def n_rows(self) -> int:
        return self.X.shape[0]

    @property
    def n_features(self) -> int:
        return self.X.shape[1]


class JobHandle:
    """The service's record of one submitted job — returned by
    `GPService.submit` and updated in place at block boundaries.

    Tenant-facing fields: `status` (PENDING/RUNNING/DONE/CANCELLED),
    `gens_done`, `best_fitness`, `history` (one best-fitness float per
    generation actually run), and — once published — `best_expression`
    plus the raw champion arrays `best_op`/`best_arg`.

    Scheduler-private fields (underscored): the occupied slot index, a
    cancel flag the next block boundary honours, and `_saved` — the
    job's island sub-state when it was preempted or repacked from a
    checkpoint taken at a different slot count, spliced back in instead
    of a fresh init on (re)admission."""

    def __init__(self, job_id: int, spec: JobSpec):
        self.job_id = job_id
        self.spec = spec
        self.status = PENDING
        self.gens_done = 0
        self.best_fitness = float("inf")
        self.history: list[float] = []
        self.best_expression: str | None = None
        self.best_op: np.ndarray | None = None
        self.best_arg: np.ndarray | None = None
        self._slot: int | None = None
        self._cancel = False
        self._saved = None  # TenantState sub-state of a preempted job

    @property
    def finished(self) -> bool:
        return self.status in (DONE, CANCELLED)

    def snapshot(self) -> dict:
        """The poll() payload: a plain-data view safe to hand out."""
        return {
            "job_id": self.job_id,
            "name": self.spec.name,
            "status": self.status,
            "gens_done": self.gens_done,
            "budget": self.spec.generations,
            "best_fitness": self.best_fitness,
            "best_expression": self.best_expression,
        }

    def __repr__(self):
        return (f"JobHandle(id={self.job_id}, name={self.spec.name!r}, "
                f"status={self.status}, gens={self.gens_done}/"
                f"{self.spec.generations}, best={self.best_fitness:g})")
