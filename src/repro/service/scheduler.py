"""GPService — multi-tenant GP-as-a-service on one compiled program.

The scheduler drives `core.engine.build_tenant_block` — ONE jitted
K-generation block over a fixed `[I, P, N]` island batch — and does all
job management at block boundaries on the host:

    submit()   validate + enqueue (a JobHandle is returned immediately)
    admit      free slots are filled from the queue (packer.pack_order);
               a job's island sub-state is spliced in eagerly
               (islands.splice_island) — fresh-initialized, or the saved
               sub-state of a preempted/repacked job
    dispatch   one block = K generations for every live slot; finished
               slots are frozen on device (tenant_active), so ragged
               budgets never block the batch
    publish    finished/cancelled jobs are lifted out (take_island),
               their champion decoded, their slot freed for the next
               queued job — all operand rebinding, never a recompile

Fault tolerance rides the seed scaffolds it was built for: the drain
loop is `runtime.fault.run_with_restarts` steps (one step = one block,
checkpointed by `ckpt.CheckpointManager`, restored after an injected or
real failure), every occupied slot beats a `HeartbeatMonitor` worker
that is `remove()`d on eviction, and a `StepMonitor` tracks per-block
wall time. A checkpoint taken at one slot count can be repacked onto a
service with another via `adopt()` — jobs are slot-position independent
because every slot-varying value is an operand.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core import fitness as fit
from repro.core.engine import TenantState
from repro.core.islands import splice_island, take_island
from repro.core.trees import TreeSpec, to_string
from repro.obs import counters as _tc
from repro.obs.metrics import BlockMonitor, Metrics
from repro.obs.trace import NULL_TRACER
from repro.runtime.fault import HeartbeatMonitor, StepMonitor, run_with_restarts
from repro.service.job import CANCELLED, DONE, PENDING, RUNNING, JobHandle, JobSpec
from repro.service.packer import JobBatch, pack_order

# every registered kernel with a whole-dataset partial_fitness — the
# default switch set a service compiles over
DEFAULT_KERNELS = ("r", "c", "m", "mse", "pearson", "r2")


class GPService:
    """A multi-tenant GP scheduler with a fixed packed layout.

    Static shape (chosen once, compiled once): `slots` islands of
    `pop_size` trees over `tree_spec` (or max_depth/n_features
    shorthand), per-slot data capacity `data_cap`, the `kernels` tuple
    the block switches over, the tournament draw size `tourn_draw` (an
    upper bound on any job's tourn_size) and `elitism`. Everything else
    is per-job and traced.

    `block_size` is K, the generations per dispatch — the admission/
    eviction (and checkpoint/restart) quantum. `checkpoint_dir` arms
    restart-from-checkpoint; `checkpoint_every` counts blocks.
    `fault_hook(block_index)` is the failure-injection point the tests
    use — it runs at the top of every scheduler step and may raise.
    `dedup`/`dedup_cap` compile the tenant block with exact-tier
    subexpression dedup (bitwise-identical fitness; see
    docs/genomes.md)."""

    def __init__(self, *, slots: int = 8, pop_size: int = 64,
                 tree_spec: TreeSpec | None = None, max_depth: int = 5,
                 n_features: int = 4, data_cap: int = 256,
                 kernels: tuple = DEFAULT_KERNELS, tourn_draw: int = 10,
                 elitism: int = 1, block_size: int = 8,
                 strategy: str = "fifo", checkpoint_dir: str | None = None,
                 checkpoint_every: int = 1, checkpoint_keep: int = 4,
                 heartbeat_deadline_s: float = 10.0, fault_hook=None,
                 tracer=None, metrics=None, dedup: str = "off",
                 dedup_cap: int = 0):
        if slots < 1:
            raise ValueError("slots must be >= 1")
        self.tree_spec = (tree_spec if tree_spec is not None
                          else TreeSpec(max_depth=max_depth,
                                        n_features=n_features))
        self.slots = slots
        self.pop_size = pop_size
        self.kernels = tuple(fit.get_kernel(k).name for k in kernels)
        self.tourn_draw = tourn_draw
        self.elitism = elitism
        self.block_size = block_size
        self.strategy = strategy
        self.batch = JobBatch(slots, self.tree_spec.n_features, data_cap,
                              self.kernels, tourn_draw)
        self.dedup = dedup
        self.dedup_cap = dedup_cap
        self._block = jax.jit(engine.build_tenant_block(
            self.tree_spec, self.kernels, tourn_draw, elitism, block_size,
            dedup=dedup, dedup_cap=dedup_cap),
            donate_argnums=(0,))
        self._state = engine.empty_tenant_state(slots, pop_size, self.tree_spec,
                                                elitism=elitism)
        self._gens = np.zeros((slots,), np.int64)  # host mirror of gens_done
        self._jobs: dict[int, JobHandle] = {}
        self._pending: list[JobHandle] = []
        self._next_id = 0
        self._fault_hook = fault_hook
        self.heartbeats = HeartbeatMonitor(deadline_s=heartbeat_deadline_s)
        self.monitor = StepMonitor()
        self.stats = {"blocks": 0, "admissions": 0, "evictions": 0,
                      "restarts": 0, "compiles": 0, "block_s_ema": None,
                      "stragglers": [], "cache_hits": 0, "cache_queries": 0,
                      "cache_hit_rate": 0.0, "frozen": 0, "tree_evals": 0}
        # observability (repro.obs): host-side only — the compiled tenant
        # block is identical with or without a tracer/metrics sink (the
        # counter stream is unconditional), so the no-recompile guarantee
        # and the block trajectories are untouched by enabling these
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else Metrics()
        self._block_monitor = BlockMonitor(self.monitor, self.metrics,
                                           self.stats)
        self._manager = None
        if checkpoint_dir:
            from repro.ckpt.checkpoint import CheckpointManager

            self._manager = CheckpointManager(checkpoint_dir,
                                              keep=checkpoint_keep,
                                              every=checkpoint_every)
        self._live_snap = None
        self._ckpt_step = 0  # block index of the restart policy's clock

    # --- tenant API -----------------------------------------------------------

    def submit(self, spec: JobSpec) -> JobHandle:
        """Validate against the compiled layout and enqueue. Returns the
        job's handle immediately — the scheduler loop (`run`/`result`)
        does the work."""
        self.batch.validate(spec)
        handle = JobHandle(self._next_id, spec)
        self._next_id += 1
        self._jobs[handle.job_id] = handle
        self._pending.append(handle)
        return handle

    def poll(self, job_id: int) -> dict:
        """Plain-data progress snapshot of one job (no device sync — the
        scheduler mirrors everything host-side at block boundaries)."""
        return self._jobs[job_id].snapshot()

    def result(self, job_id: int, *, drive: bool = True,
               max_blocks: int = 100_000) -> JobHandle:
        """The job's handle once it finished. With drive=True (default)
        the calling thread runs the scheduler loop until the whole
        queue drains — this is a single-process service; the caller IS
        the scheduler."""
        handle = self._jobs[job_id]
        if not handle.finished and drive:
            self.run(max_blocks=max_blocks)
        if not handle.finished:
            raise RuntimeError(f"job {job_id} is {handle.status} after the "
                               f"scheduler loop — raise max_blocks?")
        return handle

    def cancel(self, job_id: int) -> bool:
        """Cancel a job: a pending one leaves the queue immediately; a
        running one is evicted at the next block boundary with partial
        results. Returns False if it already finished."""
        handle = self._jobs[job_id]
        if handle.finished:
            return False
        if handle.status == PENDING:
            self._pending.remove(handle)
            handle.status = CANCELLED
            return True
        handle._cancel = True
        return True

    # --- scheduler loop -------------------------------------------------------

    def idle(self) -> bool:
        return not self._pending and not self.batch.occupied

    def run(self, *, max_blocks: int = 100_000, max_restarts: int = 3) -> "GPService":
        """Drain the queue: admit → dispatch → publish per block until no
        job is pending or resident (or `max_blocks` safety-stops).

        With a checkpoint manager, the loop runs as
        `run_with_restarts` steps — a failure (anything `fault_hook` or
        the dispatch raises) rolls back to the newest committed
        checkpoint and replays; determinism makes the replay
        bit-identical, so restarts are invisible in the results."""
        if self.idle():
            return self
        if self._manager is None:
            for _ in range(max_blocks):
                if self.idle():
                    break
                self._scheduler_step(None, self._ckpt_step)
            return self

        # commit the live state before entering the restart policy, so a
        # failure in the FIRST block of this run() cannot roll back past
        # work from a previous run() on the same service (skipped when the
        # directory is already at or past this clock — e.g. a fresh
        # process resuming someone else's checkpoints)
        from repro.ckpt.checkpoint import latest_step

        latest = latest_step(self._manager.directory)
        if latest is None or latest < self._ckpt_step:
            self._live_snap = self._make_snapshot()
            self._manager.maybe_save(self._live_snap, self._ckpt_step,
                                     force=True)
            self._manager.wait()

        _, restarts = run_with_restarts(
            lambda: self._live_snap if self._live_snap is not None
            else self._make_snapshot(),
            self._scheduler_step,
            self._ckpt_step + max_blocks, self._manager,
            max_restarts=max_restarts,
            until=lambda _snap: self.idle())
        self.stats["restarts"] += restarts
        return self

    def _scheduler_step(self, snap, i):
        """One restart-policy step == one block boundary: (re)load state
        if the policy rolled back, inject faults, admit, dispatch,
        publish. Returns the committed-checkpoint payload."""
        if snap is not None and snap is not self._live_snap:
            self._load_snapshot(snap)  # restored after a failure
        if self._fault_hook is not None:
            self._fault_hook(i)
        self._admit()
        self._dispatch_and_publish()
        self._ckpt_step = i + 1  # the restart policy's committed clock
        self._live_snap = self._make_snapshot()
        return self._live_snap

    def _admit(self):
        free = self.batch.free_slots
        if not free or not self._pending:
            return
        with self.tracer.span("admit", args={"free": len(free),
                                             "pending": len(self._pending)}):
            chosen = pack_order(self._pending, len(free), self.strategy)
            for slot, handle in zip(free, chosen):
                self._pending.remove(handle)
                if handle._saved is not None:  # preempted/repacked: resume
                    sub = jax.tree.map(jnp.asarray, handle._saved)
                    handle._saved = None
                else:
                    sub = engine.init_tenant_slot(
                        jax.random.PRNGKey(handle.spec.seed), self.pop_size,
                        self.tree_spec, elitism=self.elitism)
                self._state = splice_island(self._state, slot, sub)
                self._gens[slot] = int(sub.gens_done)
                self.batch.admit(slot, handle)
                handle.status = RUNNING
                self.heartbeats.beat(self._worker_id(handle))
                self.stats["admissions"] += 1
                self.metrics.inc("admissions")
                # async track: one lifetime lane per job, admission → publish
                self.tracer.begin_async("job", handle.job_id, cat="service",
                                        args={"slot": slot})
        self.metrics.gauge("occupied_slots", len(self.batch.occupied))

    def _dispatch_and_publish(self):
        X, y, w, params = self.batch.operands()
        with self._block_monitor, self.tracer.span(
                "dispatch", args={"occupied": len(self.batch.occupied)}):
            self._state, hist, counters = self._block(self._state, X, y, w,
                                                      params)
            # ONE host sync per block: counters, champions and the
            # per-generation streams come back together
            host, hist, crows = jax.device_get((self._state, hist, counters))
        hist = np.asarray(hist)  # [K, I]
        self._absorb_counters(crows)
        self.stats["compiles"] = self._compile_count()
        self.metrics.gauge("compiles", self.stats["compiles"])

        budgets = np.asarray(params.budget)
        stops = np.asarray(params.stop)
        total_ran = 0
        for slot, handle in self.batch.occupied:
            ran = int(host.gens_done[slot]) - int(self._gens[slot])
            total_ran += ran
            self._gens[slot] = int(host.gens_done[slot])
            handle.gens_done = int(host.gens_done[slot])
            handle.best_fitness = float(host.best_fitness[slot])
            handle.history.extend(float(b) for b in hist[:ran, slot])
            self.heartbeats.beat(self._worker_id(handle))
            if ran and self.monitor.last:
                # per-tenant progress rate over this block's wall time
                self.metrics.observe("tenant_gens_per_s",
                                     ran / self.monitor.last)
            finished = (handle.gens_done >= int(budgets[slot])
                        or handle.best_fitness <= float(stops[slot]))
            if finished or handle._cancel:
                self._publish(slot, handle, host,
                              DONE if finished else CANCELLED)
        if total_ran and self.monitor.last:
            self.metrics.gauge("gens_per_s", total_ran / self.monitor.last)
        self.metrics.gauge("occupied_slots", len(self.batch.occupied))

    def _absorb_counters(self, rows):
        """Fold a tenant block's int32[K, C] telemetry stream
        (repro.obs.counters) into `stats` + the metrics registry; the
        elite-cache hit rate is derived from the accumulated totals."""
        tot = _tc.totals(rows)
        tot.pop("migrations", None)  # tenant slots never migrate
        for name, v in tot.items():
            self.stats[name] = self.stats.get(name, 0) + v
            if v:
                self.metrics.inc(name, v)
        self.stats["cache_hit_rate"] = _tc.hit_rate(self.stats)
        self.metrics.gauge("cache_hit_rate", self.stats["cache_hit_rate"])
        self.metrics.emit("counters", **tot)

    def _publish(self, slot: int, handle: JobHandle, host: TenantState,
                 status: str):
        handle.best_op = np.asarray(host.best_op[slot]).copy()
        handle.best_arg = np.asarray(host.best_arg[slot]).copy()
        if np.isfinite(handle.best_fitness):
            handle.best_expression = to_string(
                handle.best_op, handle.best_arg,
                feature_names=handle.spec.feature_names,
                const_table=np.asarray(self.tree_spec.const_table()),
                genome=self.tree_spec.genome)
        handle.status = status
        handle._cancel = False
        self.batch.evict(slot)
        # the slot's worker left on purpose — forget it, or dead_workers()
        # would report every finished job forever
        self.heartbeats.remove(self._worker_id(handle))
        self.stats["evictions"] += 1
        self.metrics.inc("evictions")
        self.tracer.end_async("job", handle.job_id, cat="service",
                              args={"status": status,
                                    "gens": handle.gens_done})
        self.tracer.instant("publish", cat="service",
                            args={"job": handle.job_id, "status": status})

    def _worker_id(self, handle: JobHandle) -> str:
        return f"job-{handle.job_id}"

    def _compile_count(self) -> int:
        """How many programs the tenant block compiled — the service's
        no-recompile guarantee pins this at 1 across every admission/
        eviction. Falls back to the blocks counter's floor if the jax
        version hides the cache."""
        try:
            return int(self._block._cache_size())
        except AttributeError:
            return 1 if self.stats["blocks"] else 0

    # --- checkpoint payload ---------------------------------------------------

    def _make_snapshot(self) -> dict:
        """Committed-checkpoint payload: the device state (host-gathered),
        the parameter table and the slot→job map. Data buffers are NOT
        checkpointed — they are derivable from the JobSpecs, which the
        submitting process re-provides (`submit` is the durable log)."""
        slot_ids = np.full((self.slots,), -1, np.int64)
        for i, h in self.batch.occupied:
            slot_ids[i] = h.job_id
        return {"state": jax.tree.map(np.asarray, jax.device_get(self._state)),
                "params": self.batch.params_host(),
                "slot_ids": slot_ids}

    def _load_snapshot(self, snap: dict):
        """Roll the whole service back to a committed checkpoint: device
        state, parameter table, slot map, and every affected handle's
        host mirror (status, counters, history truncation). Jobs that
        finished AFTER the checkpoint return to their slots and re-run
        their tail — determinism republishes identical results."""
        self._state = jax.tree.map(jnp.asarray, snap["state"])
        self.batch.restore_params(snap["params"])
        gens = np.asarray(snap["state"].gens_done)
        best = np.asarray(snap["state"].best_fitness)
        slot_ids = np.asarray(snap["slot_ids"])
        self.batch.slots = [None] * self.slots
        slotted = set()
        for i, jid in enumerate(slot_ids):
            if jid < 0:
                continue
            handle = self._jobs[int(jid)]
            slotted.add(int(jid))
            self.batch.slots[i] = handle
            handle._slot = i
            handle._saved = None
            handle.status = RUNNING
            # a rollback puts the job back in flight: reopen its lifetime
            # lane (idempotent — a still-open lane is untouched)
            self.tracer.begin_async("job", handle.job_id, cat="service",
                                    args={"slot": i, "rollback": True})
            handle.gens_done = int(gens[i])
            handle.best_fitness = float(best[i])
            handle.history = handle.history[:int(gens[i])]
            # rebuild the slot's data row from the spec (not checkpointed)
            from repro.service.packer import slot_buffers

            X, yb, wb = slot_buffers(handle.spec, self.batch.n_features,
                                     self.batch.data_cap)
            self.batch._X[i], self.batch._y[i], self.batch._w[i] = X, yb, wb
        self.batch._dirty = True
        # everything not finished and not resident goes back to the queue
        self._pending = [h for jid, h in sorted(self._jobs.items())
                         if jid not in slotted and not h.finished
                         and h.status != CANCELLED]
        for h in self._pending:
            h.status = PENDING
            h._slot = None
        self._gens = gens.astype(np.int64).copy()
        self._live_snap = snap

    def adopt(self, snap: dict) -> "GPService":
        """Repack a checkpoint taken at a DIFFERENT slot count onto this
        service (elastic resume): every occupied slot's island sub-state
        is lifted out (`take_island`) and parked on its job's handle;
        the normal admission path splices it into whatever slot this
        layout has free. Requires the jobs to have been re-submitted
        (ids must match) and the static tree/population shape to agree;
        slot positions don't matter — every slot-varying value is an
        operand."""
        state = snap["state"]
        if state.op.shape[1:] != (self.pop_size, self.tree_spec.num_nodes):
            raise ValueError(
                f"checkpoint population shape {state.op.shape[1:]} does not "
                f"match this service's ({self.pop_size}, "
                f"{self.tree_spec.num_nodes}) — elastic resume only varies "
                f"the slot count")
        for i, jid in enumerate(np.asarray(snap["slot_ids"])):
            if jid < 0:
                continue
            handle = self._jobs[int(jid)]
            handle._saved = jax.tree.map(np.asarray, take_island(state, i))
            handle.gens_done = int(np.asarray(state.gens_done)[i])
            handle.history = handle.history[:handle.gens_done]
            handle.best_fitness = float(np.asarray(state.best_fitness)[i])
            if handle not in self._pending:
                self._pending.append(handle)
            handle.status = PENDING
            handle._slot = None
        self._pending.sort(key=lambda h: h.job_id)
        return self


def run_jobs(specs: list[JobSpec], **service_kw) -> list[JobHandle]:
    """Convenience one-shot: submit every spec, drain, return handles in
    submit order (the launch CLI and benchmarks ride this)."""
    svc = GPService(**service_kw)
    handles = [svc.submit(s) for s in specs]
    svc.run()
    return handles
