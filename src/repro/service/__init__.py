"""GP-as-a-service: multi-tenant job scheduling on the island layout.

The ROADMAP's serving story made concrete: thousands of concurrent SMALL
GP runs — exactly the tens-to-hundreds-of-rows regime where the paper
measures its vectorization wins — packed into ONE compiled island-batch
program. A user job is an island with no migration; everything
job-specific (data slice, fitness kernel, operator mix, tournament size,
point rate, stop bar, budget) is a traced operand, so jobs are admitted
and evicted at block boundaries without ever recompiling.

    from repro.service import GPService, JobSpec

    svc = GPService(slots=8, pop_size=64, n_features=3, data_cap=128)
    h = svc.submit(JobSpec(X, y, kernel="r", generations=40, seed=7))
    svc.run()                  # drain the queue (the caller is the scheduler)
    print(svc.result(h.job_id).best_expression)

See docs/service.md for the job lifecycle, the packing layout and the
checkpoint/restart + elastic-resume story."""
from repro.service.job import (CANCELLED, DONE, PENDING, RUNNING, JobHandle,
                               JobSpec)
from repro.service.packer import JobBatch, pack_order, slot_buffers
from repro.service.scheduler import DEFAULT_KERNELS, GPService, run_jobs

__all__ = [
    "CANCELLED", "DONE", "PENDING", "RUNNING",
    "JobHandle", "JobSpec", "JobBatch", "pack_order", "slot_buffers",
    "DEFAULT_KERNELS", "GPService", "run_jobs",
]
