"""Jitted public wrappers for the GP eval+fitness kernel.

Handles padding (population to pop_tile, data to data_tile with a zero
weight mask), picks the terminal-gather strategy, and sizes the data tile
to a VMEM budget. Two surfaces:

    fitness(...)  f32[P] finalized fitness — phase 1 moments accumulated
                  across the Pallas data grid, phase 2 reduce on the
                  result (a [P, M] @ tiny elementwise epilogue)
    moments(...)  f32[P, M] phase-1 moments only — what a mesh step
                  `psum`s across the data axis before finalizing

`impl="jnp"` falls through to the oracle so callers (engine, benchmarks)
can flip implementations with one flag.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import eval as _eval
from repro.core.fitness import FitnessSpec
from repro.core.trees import TreeSpec
from repro.kernels import ref as _ref
from repro.kernels.gp_eval import (eval_fitness_pallas,
                                   eval_fitness_pallas_from_preds,
                                   eval_fitness_pallas_from_subtrees,
                                   eval_fitness_pallas_postfix)

_VMEM_BUDGET = 12 * 2**20  # bytes; leave headroom under ~16 MB/core


def pick_tiles(n_features: int, n_nodes: int, pop: int, data: int,
               pop_tile: int = 8, data_tile: int = 1024, gather: str | None = None):
    """Choose (pop_tile, data_tile, gather) under the VMEM budget.

    VMEM per block ≈ X tile + term/vals buffers (+ onehot when used):
        X:      F · Db · 4
        term:   Pb · N · Db · 4     (dominant)
        vals:   ≤ Pb · (N+1) · Db · 4
        onehot: Pb · N · F · 4
    """
    if gather is None:
        gather = "onehot" if n_features <= 64 else "vmem"
    Db = data_tile

    def vmem(Db):
        base = 4 * (n_features * Db + 2 * pop_tile * (n_nodes + 1) * Db)
        if gather == "onehot":
            base += 4 * pop_tile * n_nodes * n_features
        return base

    while Db > 128 and vmem(Db) > _VMEM_BUDGET:
        Db //= 2
    return pop_tile, Db, gather


def pick_tiles_postfix(n_features: int, stack_size: int, pop: int, data: int,
                       pop_tile: int = 8, data_tile: int = 1024,
                       gather: str | None = None, dedup_rows: int = 0):
    """Tile pick for the postfix stack kernel. The carried state is a
    [Pb, S, Db] stack (S = max_depth + 1), ~S/N of the tree kernel's
    node-resident buffers, so the data tile can grow under the same VMEM
    budget — fewer, larger grid blocks amortize the per-instruction loop.
    Gather defaults to "vmem": the stack kernel reads ONE terminal row
    per instruction, where a dynamic take beats a one-hot matmul.

    `dedup_rows` (the dedup unique-table cap) charges the budget for the
    f32[U, Db] unique-subtree scratch the in-VMEM dedup gather kernel
    keeps resident per block. `_moments_padded` never lets this change
    the picked tile — the dedup-off pick (``dedup_rows=0``) anchors the
    merge order for the bitwise contract; the charged pick is the VMEM
    re-check that decides whether the in-VMEM gather kernel is safe or
    the gather must spill to HBM (`eval_fitness_pallas_from_preds`)."""
    if gather is None:
        gather = "vmem"
    Db = data_tile

    def vmem(Db):
        return _postfix_vmem(n_features, stack_size, pop_tile, Db, dedup_rows)

    while Db * 2 <= data and vmem(Db * 2) <= _VMEM_BUDGET and Db < 2048:
        Db *= 2
    while Db > 128 and vmem(Db) > _VMEM_BUDGET:
        Db //= 2
    return pop_tile, Db, gather


def _postfix_vmem(n_features: int, stack_size: int, pop_tile: int, Db: int,
                  dedup_rows: int = 0) -> int:
    """VMEM bytes per block of the postfix stack kernel: X tile + stack
    + the handful of [Pb, Db] per-instruction temps + the dedup
    unique-subtree scratch when that kernel is live."""
    return 4 * (n_features * Db + pop_tile * (stack_size + 8) * Db
                + dedup_rows * Db)


def _moments_padded(op, arg, X, y, const_table, tree_spec: TreeSpec,
                    fit_spec: FitnessSpec, weight, data_tile: int, pop_tile: int,
                    gather: str | None, interpret: bool | None,
                    dedup: str = "off", dedup_cap: int = 0):
    """Pad to tile multiples and run the fused kernel: f32[P, M] moments.
    Padded data points carry weight 0.0, so every moment they touch is an
    exact 0.0 and the grid accumulation stays padding-invariant.

    Any ``dedup != "off"`` engages the exact-tier subexpression dedup
    for postfix genomes: build the population's unique-subtree schedule
    (core/eval.build_dedup_plan), evaluate each distinct subtree once,
    and run a gather+moments kernel over the f32[cap, D] unique table.
    The tile geometry is ALWAYS the plain (dedup_rows=0) pick — the
    (pop, data) grid and merge order the dedup-off kernel uses — so
    moments stay bitwise identical to dedup-off. When the uniq scratch
    fits VMEM at that pick (re-checked by charging `dedup_rows=cap` to
    the same budget model) the in-VMEM gather kernel runs; otherwise the
    gather happens at the XLA level (HBM `uniq[root]`) and the spill
    kernel streams plain-geometry blocks. Unique-table overflow
    `lax.cond`s back onto the plain kernel."""
    P, N = op.shape
    F, D = X.shape
    if tree_spec.genome == "postfix":
        cap = (_eval.resolve_dedup_cap(dedup_cap, P, N)
               if dedup != "off" else 0)
        pop_tile, data_tile, gather = pick_tiles_postfix(
            F, tree_spec.stack_size, P, D, pop_tile, data_tile, gather)
        # Would the f32[cap, Db] unique table still fit VMEM at this
        # exact pick?  If not, spill the gather to HBM instead of
        # shrinking the tile (which would change the merge order).
        dedup_fits = (cap == 0 or _postfix_vmem(
            F, tree_spec.stack_size, pop_tile, data_tile,
            dedup_rows=cap) <= _VMEM_BUDGET)
    else:
        pop_tile, data_tile, gather = pick_tiles(F, N, P, D, pop_tile,
                                                 data_tile, gather)

    pad_p = (-P) % pop_tile
    pad_d = (-D) % data_tile
    weight = (jnp.ones((D,), jnp.float32) if weight is None
              else weight.astype(jnp.float32))
    if pad_p:
        op = jnp.pad(op, ((0, pad_p), (0, 0)))
        arg = jnp.pad(arg, ((0, pad_p), (0, 0)))
    if pad_d:
        X = jnp.pad(X, ((0, 0), (0, pad_d)))
        y = jnp.pad(y, (0, pad_d))
        weight = jnp.pad(weight, (0, pad_d))

    fn_codes = tuple(int(c) for c in tree_spec.fn_set.opcodes)
    if tree_spec.genome == "postfix":
        # Sort rows by active length so each pop tile's fori trip count is
        # its own max length (short-program tiles finish early) — this
        # sorting is where most of the postfix speedup lives. Moments are
        # per-row, so sort → eval → unsort is exact; padded rows (len 0)
        # sort to the front and are sliced off after the unsort.
        lens = (op != 0).sum(-1).astype(jnp.int32)
        order = jnp.argsort(lens)
        op_s, arg_s = op[order], arg[order]

        def _plain():
            out = eval_fitness_pallas_postfix(
                op_s, arg_s, lens[order], X, y, weight, const_table,
                stack_size=tree_spec.stack_size, kernel=fit_spec.kernel,
                n_classes=fit_spec.n_classes, precision=fit_spec.precision,
                gather=gather, pop_tile=pop_tile, data_tile=data_tile,
                interpret=interpret, fn_codes=fn_codes)
            return out[jnp.argsort(order)]

        if dedup != "off":
            plan = _eval.build_dedup_plan(op, arg, tree_spec, cap)

            def _dedup():
                uniq = _eval.evaluate_unique_subtrees(plan, X, const_table,
                                                      tree_spec)
                if dedup_fits:
                    return eval_fitness_pallas_from_subtrees(
                        plan.root, uniq, y, weight, kernel=fit_spec.kernel,
                        n_classes=fit_spec.n_classes,
                        precision=fit_spec.precision, pop_tile=pop_tile,
                        data_tile=data_tile, interpret=interpret)
                preds = jnp.take(uniq, jnp.clip(plan.root, 0, cap - 1),
                                 axis=0)
                return eval_fitness_pallas_from_preds(
                    preds, y, weight, kernel=fit_spec.kernel,
                    n_classes=fit_spec.n_classes,
                    precision=fit_spec.precision, pop_tile=pop_tile,
                    data_tile=data_tile, interpret=interpret)

            return jax.lax.cond(plan.overflow, _plain, _dedup)[:P]
        return _plain()[:P]
    out = eval_fitness_pallas(
        op, arg, X, y, weight, const_table, max_depth=tree_spec.max_depth,
        kernel=fit_spec.kernel, n_classes=fit_spec.n_classes,
        precision=fit_spec.precision, gather=gather, pop_tile=pop_tile,
        data_tile=data_tile, interpret=interpret, fn_codes=fn_codes)
    return out[:P]


@partial(jax.jit, static_argnames=("tree_spec", "fit_spec", "data_tile", "pop_tile",
                                   "gather", "interpret", "dedup", "dedup_cap"))
def moments(op, arg, X, y, const_table, tree_spec: TreeSpec, fit_spec: FitnessSpec,
            *, weight=None, data_tile: int = 1024, pop_tile: int = 8,
            gather: str | None = None, interpret: bool | None = None,
            dedup: str = "off", dedup_cap: int = 0):
    """f32[P, M] phase-1 moments of every tree against (X:[F,D], y:[D]),
    fused with evaluation on the Pallas path. Sum with the other shards'
    moments (e.g. `lax.psum` on the mesh data axis), then finalize with
    `get_kernel(fit_spec.kernel).reduce_moments`."""
    from repro.core.fitness import get_kernel

    if get_kernel(fit_spec.kernel).moments is None:
        raise ValueError(f"fitness kernel {fit_spec.kernel!r} defines no moment "
                         f"pass; it cannot accumulate across data tiles/shards")
    return _moments_padded(op, arg, X, y, const_table, tree_spec, fit_spec,
                           weight, data_tile, pop_tile, gather, interpret,
                           dedup=dedup, dedup_cap=dedup_cap)


@partial(jax.jit, static_argnames=("tree_spec", "fit_spec", "data_tile", "pop_tile",
                                   "gather", "impl", "interpret", "dedup",
                                   "dedup_cap"))
def stream_moments(acc, op, arg, X, y, const_table, tree_spec: TreeSpec,
                   fit_spec: FitnessSpec, *, weight=None, data_tile: int = 1024,
                   pop_tile: int = 8, gather: str | None = None,
                   impl: str = "pallas", interpret: bool | None = None,
                   dedup: str = "off", dedup_cap: int = 0):
    """One streaming fold step, ONE dispatch: phase-1 moments of this
    data chunk merged into the running f32[P, M] accumulator `acc` via
    the kernel's merge (elementwise sum, or `combine_moments`). Seed the
    fold with zeros — the merge identity by contract — and finalize the
    final accumulator once with `reduce_moments`. Every chunk of a
    `data/loader.ChunkedDataset` has the same fixed shape, so the whole
    stream re-enters this one compiled program."""
    from repro.core.fitness import get_kernel

    kern = get_kernel(fit_spec.kernel)
    if kern.moments is None:
        raise ValueError(f"fitness kernel {fit_spec.kernel!r} defines no moment "
                         f"pass; it cannot accumulate across data chunks")
    if impl == "jnp":
        m = _ref.moments_ref_tiled(op, arg, X, y, const_table, tree_spec,
                                   fit_spec, weight=weight, dedup=dedup,
                                   dedup_cap=dedup_cap)
    else:
        m = _moments_padded(op, arg, X, y, const_table, tree_spec, fit_spec,
                            weight, data_tile, pop_tile, gather, interpret,
                            dedup=dedup, dedup_cap=dedup_cap)
    return kern.merge_moments(acc, m, fit_spec)


@partial(jax.jit, static_argnames=("tree_spec", "fit_spec", "data_tile", "pop_tile",
                                   "gather", "impl", "interpret", "dedup",
                                   "dedup_cap"))
def fitness(op, arg, X, y, const_table, tree_spec: TreeSpec, fit_spec: FitnessSpec,
            *, weight=None, data_tile: int = 1024, pop_tile: int = 8,
            gather: str | None = None, impl: str = "pallas",
            interpret: bool | None = None,
            dedup: str = "off", dedup_cap: int = 0):
    """f32[P] fitness (minimize) of every tree against (X:[F,D], y:[D]).

    `weight` is an optional f32[D] mask (0.0 on dataset-padding points,
    e.g. from data/loader.pad_rows); it composes with the kernel's own
    data-tile padding mask so padded datasets score exactly. Every
    registered kernel with a moment pass — decomposable one-moment
    objectives and two-pass statistics (pearson, r2) alike — runs the
    fused Pallas grid; only legacy kernels registered without moments
    fall back to the un-tiled reference path."""
    from repro.core.fitness import get_kernel

    kern = get_kernel(fit_spec.kernel)
    if impl == "jnp" or kern.moments is None:
        return _ref.fitness_ref(op, arg, X, y, const_table, tree_spec, fit_spec,
                                weight=weight, dedup=dedup, dedup_cap=dedup_cap)
    m = _moments_padded(op, arg, X, y, const_table, tree_spec, fit_spec,
                        weight, data_tile, pop_tile, gather, interpret,
                        dedup=dedup, dedup_cap=dedup_cap)
    return kern.reduce_moments(m, fit_spec)
