"""Pallas TPU kernel: fused GP population evaluation + fitness reduction.

This is the compute hot spot the paper optimizes (§2.5: "the evaluation of
the multivariate expression derived from each GP tree against the entire
training dataset"). The pure-jnp path (kernels/ref.py → core/eval.py)
materializes a [pop, nodes, data] intermediate in HBM between the
level-sweep and the fitness reduction; this kernel keeps the whole
evaluation frontier in VMEM per (population-tile × data-tile) block and
writes back only the [pop] fitness partials — turning a memory-bound
HBM-streaming computation into a VMEM-resident one.

TPU adaptation of the terminal lookup (DESIGN.md §2): arbitrary-index
gathers are the one primitive that does not lower cleanly to Mosaic, so
feature selection is expressed two ways:

  gather="onehot"  one-hot(arg) @ X — an MXU matmul. Guaranteed lowering,
                   and for small feature counts the F-fold FLOP blowup is
                   cheaper than a VPU gather round-trip.
  gather="vmem"    jnp.take on the VMEM-resident X tile (sublane-dim
                   dynamic gather; supported by recent Mosaic, and by
                   interpret mode used for validation on CPU).

ops.py picks per-call based on feature count and exposes the choice as a
§Perf hillclimbing axis.

Grid: (pop_tiles, data_tiles); the data dimension is innermost so each
population tile's output block stays resident while fitness partials
accumulate across data tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import fitness as fit
from repro.core import primitives as prim

_FN_BASE = 3


def _apply_function_inline(op, lhs, rhs, fn_codes=None):
    """Branch-free opcode dispatch (same contract as primitives.apply_function,
    restated here so the kernel body has no module-level closure surprises).
    fn_codes restricts the select chain to the run's operator set."""
    codes = (list(fn_codes) if fn_codes is not None
             else list(range(_FN_BASE, _FN_BASE + len(prim.FUNCTIONS))))
    branches = [prim.FUNCTIONS[c - _FN_BASE].fn(lhs, rhs) for c in codes]
    preds = [op == c for c in codes]
    return jnp.select(preds, branches, jnp.zeros_like(lhs))


def _eval_fitness_kernel(op_ref, arg_ref, x_ref, y_ref, w_ref, const_ref, out_ref,
                         *, max_depth: int, n_features: int, n_consts: int,
                         kernel: str, n_classes: int, precision: float, gather: str,
                         fn_codes=None):
    """One (pop_tile, data_tile) block: evaluate + reduce fitness partial."""
    j = pl.program_id(1)
    ops = op_ref[...]  # int32[Pb, N]
    args = arg_ref[...]  # int32[Pb, N]
    X = x_ref[...]  # f32[F, Db]
    Pb, N = ops.shape
    Db = X.shape[1]

    # ---- terminal values for every slot ------------------------------------
    if gather == "onehot":
        # MXU path: feature select as one-hot matmul, [Pb*N, F] @ [F, Db].
        f_iota = jax.lax.broadcasted_iota(jnp.int32, (Pb, N, n_features), 2)
        onehot = (f_iota == args[:, :, None]).astype(jnp.float32)
        feat = jax.lax.dot_general(
            onehot.reshape(Pb * N, n_features), X,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(Pb, N, Db)
    else:
        # VMEM gather path: dynamic row-select from the resident data tile.
        feat = jnp.take(X, jnp.clip(args, 0, n_features - 1), axis=0)  # [Pb, N, Db]

    c_iota = jax.lax.broadcasted_iota(jnp.int32, (Pb, N, n_consts), 2)
    c_onehot = (c_iota == args[:, :, None]).astype(jnp.float32)
    cons = jnp.einsum("pnc,c->pn", c_onehot, const_ref[...])  # [Pb, N]

    term = jnp.where((ops == prim.FEATURE)[:, :, None], feat,
                     jnp.broadcast_to(cons[:, :, None], (Pb, N, Db)))

    # ---- level-synchronous sweep, frontier resident in VMEM ----------------
    vals = None  # child-level buffer [Pb, 2**(d+1), Db]
    for d in range(max_depth, -1, -1):
        lo, w = 2**d - 1, 2**d
        opd = ops[:, lo:lo + w, None]
        node = term[:, lo:lo + w]
        if vals is not None:
            pair = vals.reshape(Pb, w, 2, Db)
            fn = _apply_function_inline(opd, pair[:, :, 0], pair[:, :, 1], fn_codes)
            node = jnp.where(opd >= _FN_BASE, fn, node)
        vals = jnp.where(opd == prim.EMPTY, 0.0, node)
    preds = vals[:, 0]  # [Pb, Db]

    # ---- fused moment partial (w masks out data padding) --------------------
    # Phase 1 of the two-pass protocol: the registered FitnessKernel's
    # `moments` (pure jnp, so it traces inside the Pallas body) runs in the
    # same w_ref-masked inner loop as the evaluation, and the [Pb, M]
    # moment partials accumulate across the data grid. Decomposable
    # kernels are the M=1 case (their moment IS the fitness partial);
    # two-pass kernels (pearson, r2) finalize in ops.fitness after the
    # grid sum — so every kernel runs fused, on any data tiling.
    y = y_ref[...]  # f32[Db]
    wgt = w_ref[...]  # f32[Db]
    spec = fit.FitnessSpec(kernel, n_classes=n_classes, precision=precision)
    kern = fit.get_kernel(kernel)
    partial = kern.moments(preds, y, wgt, spec)  # [Pb, M]

    # merge across data tiles (innermost grid dim revisits the out
    # block): elementwise sum, or the kernel's pairwise combine —
    # pearson/r2's Chan merge of centered moments is plain jnp, so it
    # traces inside the Pallas body like any other moment math
    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        out_ref[...] = kern.merge_moments(out_ref[...], partial, spec)


def eval_fitness_pallas(op, arg, X, y, weight, const_table, *, max_depth: int,
                        kernel: str = "r", n_classes: int = 3, precision: float = 1e-4,
                        gather: str = "onehot", pop_tile: int = 8, data_tile: int = 1024,
                        interpret: bool | None = None, fn_codes=None):
    """Fused eval+moments over pre-padded inputs.

    op, arg:  int32[P, N]   P % pop_tile == 0
    X:        f32[F, D]     D % data_tile == 0
    y, weight f32[D]        weight is 1.0 on valid points, 0.0 on padding —
                            both the wrapper's tile padding AND any dataset
                            padding the caller threaded in (loader.pad_rows),
                            composed upstream in ops.fitness
    returns   f32[P, M]     the kernel's fully-accumulated weighted moments
                            (M = FitnessKernel.n_moments; for decomposable
                            kernels M == 1 and [:, 0] is the fitness);
                            finalize with FitnessKernel.reduce_moments
    """
    P, N = op.shape
    F, D = X.shape
    assert P % pop_tile == 0 and D % data_tile == 0, (P, D, pop_tile, data_tile)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_moments = fit.get_kernel(kernel).n_moments

    grid = (P // pop_tile, D // data_tile)
    body = functools.partial(
        _eval_fitness_kernel, max_depth=max_depth, n_features=F,
        n_consts=const_table.shape[0], kernel=kernel, n_classes=n_classes,
        precision=precision, gather=gather, fn_codes=fn_codes)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((pop_tile, N), lambda i, j: (i, 0)),
            pl.BlockSpec((pop_tile, N), lambda i, j: (i, 0)),
            pl.BlockSpec((F, data_tile), lambda i, j: (0, j)),
            pl.BlockSpec((data_tile,), lambda i, j: (j,)),
            pl.BlockSpec((data_tile,), lambda i, j: (j,)),
            pl.BlockSpec((const_table.shape[0],), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((pop_tile, n_moments), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((P, n_moments), jnp.float32),
        interpret=interpret,
    )(op, arg, X.astype(jnp.float32), y.astype(jnp.float32),
      weight.astype(jnp.float32), const_table.astype(jnp.float32))
