"""Pallas TPU kernel: fused GP population evaluation + fitness reduction.

This is the compute hot spot the paper optimizes (§2.5: "the evaluation of
the multivariate expression derived from each GP tree against the entire
training dataset"). The pure-jnp path (kernels/ref.py → core/eval.py)
materializes a [pop, nodes, data] intermediate in HBM between the
level-sweep and the fitness reduction; this kernel keeps the whole
evaluation frontier in VMEM per (population-tile × data-tile) block and
writes back only the [pop] fitness partials — turning a memory-bound
HBM-streaming computation into a VMEM-resident one.

TPU adaptation of the terminal lookup (DESIGN.md §2): arbitrary-index
gathers are the one primitive that does not lower cleanly to Mosaic, so
feature selection is expressed two ways:

  gather="onehot"  one-hot(arg) @ X — an MXU matmul. Guaranteed lowering,
                   and for small feature counts the F-fold FLOP blowup is
                   cheaper than a VPU gather round-trip.
  gather="vmem"    jnp.take on the VMEM-resident X tile (sublane-dim
                   dynamic gather; supported by recent Mosaic, and by
                   interpret mode used for validation on CPU).

ops.py picks per-call based on feature count and exposes the choice as a
§Perf hillclimbing axis.

Grid: (pop_tiles, data_tiles); the data dimension is innermost so each
population tile's output block stays resident while fitness partials
accumulate across data tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import fitness as fit
from repro.core import primitives as prim

_FN_BASE = 3


def _apply_function_inline(op, lhs, rhs, fn_codes=None):
    """Branch-free opcode dispatch (same contract as primitives.apply_function,
    restated here so the kernel body has no module-level closure surprises).
    fn_codes restricts the select chain to the run's operator set."""
    codes = (list(fn_codes) if fn_codes is not None
             else list(range(_FN_BASE, _FN_BASE + len(prim.FUNCTIONS))))
    branches = [prim.FUNCTIONS[c - _FN_BASE].fn(lhs, rhs) for c in codes]
    preds = [op == c for c in codes]
    return jnp.select(preds, branches, jnp.zeros_like(lhs))


def _eval_fitness_kernel(op_ref, arg_ref, x_ref, y_ref, w_ref, const_ref, out_ref,
                         *, max_depth: int, n_features: int, n_consts: int,
                         kernel: str, n_classes: int, precision: float, gather: str,
                         fn_codes=None):
    """One (pop_tile, data_tile) block: evaluate + reduce fitness partial."""
    j = pl.program_id(1)
    ops = op_ref[...]  # int32[Pb, N]
    args = arg_ref[...]  # int32[Pb, N]
    X = x_ref[...]  # f32[F, Db]
    Pb, N = ops.shape
    Db = X.shape[1]

    # ---- terminal values for every slot ------------------------------------
    if gather == "onehot":
        # MXU path: feature select as one-hot matmul, [Pb*N, F] @ [F, Db].
        f_iota = jax.lax.broadcasted_iota(jnp.int32, (Pb, N, n_features), 2)
        onehot = (f_iota == args[:, :, None]).astype(jnp.float32)
        feat = jax.lax.dot_general(
            onehot.reshape(Pb * N, n_features), X,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(Pb, N, Db)
    else:
        # VMEM gather path: dynamic row-select from the resident data tile.
        feat = jnp.take(X, jnp.clip(args, 0, n_features - 1), axis=0)  # [Pb, N, Db]

    c_iota = jax.lax.broadcasted_iota(jnp.int32, (Pb, N, n_consts), 2)
    c_onehot = (c_iota == args[:, :, None]).astype(jnp.float32)
    cons = jnp.einsum("pnc,c->pn", c_onehot, const_ref[...])  # [Pb, N]

    term = jnp.where((ops == prim.FEATURE)[:, :, None], feat,
                     jnp.broadcast_to(cons[:, :, None], (Pb, N, Db)))

    # ---- level-synchronous sweep, frontier resident in VMEM ----------------
    vals = None  # child-level buffer [Pb, 2**(d+1), Db]
    for d in range(max_depth, -1, -1):
        lo, w = 2**d - 1, 2**d
        opd = ops[:, lo:lo + w, None]
        node = term[:, lo:lo + w]
        if vals is not None:
            pair = vals.reshape(Pb, w, 2, Db)
            fn = _apply_function_inline(opd, pair[:, :, 0], pair[:, :, 1], fn_codes)
            node = jnp.where(opd >= _FN_BASE, fn, node)
        vals = jnp.where(opd == prim.EMPTY, 0.0, node)
    preds = vals[:, 0]  # [Pb, Db]

    # ---- fused moment partial (w masks out data padding) --------------------
    # Phase 1 of the two-pass protocol: the registered FitnessKernel's
    # `moments` (pure jnp, so it traces inside the Pallas body) runs in the
    # same w_ref-masked inner loop as the evaluation, and the [Pb, M]
    # moment partials accumulate across the data grid. Decomposable
    # kernels are the M=1 case (their moment IS the fitness partial);
    # two-pass kernels (pearson, r2) finalize in ops.fitness after the
    # grid sum — so every kernel runs fused, on any data tiling.
    y = y_ref[...]  # f32[Db]
    wgt = w_ref[...]  # f32[Db]
    spec = fit.FitnessSpec(kernel, n_classes=n_classes, precision=precision)
    kern = fit.get_kernel(kernel)
    partial = kern.moments(preds, y, wgt, spec)  # [Pb, M]

    # merge across data tiles (innermost grid dim revisits the out
    # block): elementwise sum, or the kernel's pairwise combine —
    # pearson/r2's Chan merge of centered moments is plain jnp, so it
    # traces inside the Pallas body like any other moment math
    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        out_ref[...] = kern.merge_moments(out_ref[...], partial, spec)


def _eval_fitness_postfix_kernel(op_ref, arg_ref, len_ref, x_ref,
                                 y_ref, w_ref, const_ref, out_ref, *,
                                 stack_size: int, n_features: int,
                                 n_consts: int, kernel: str,
                                 n_classes: int, precision: float, gather: str,
                                 fn_codes=None):
    """One (pop_tile, data_tile) block of the postfix stack interpreter.

    Instead of the tree kernel's level sweep over all NODES slots, each
    iteration executes ONE postfix instruction for the whole tile: a
    `fori_loop` whose trip count is the tile's max active length — with
    ops.py sorting rows by length, short-program tiles finish early,
    which is where the linear genome's speedup comes from.

    Per-instruction state is a shift-register operand stack f32[Pb, S, Db]
    with S = TreeSpec.stack_size = max_depth + 1 (invariant P5 bounds the
    operand depth, so S slots always suffice). Slot 0 is the top:
    terminals shift-push their value, unary functions replace the top,
    binary functions fold the top two and shift up. Both operands are
    the top two slots by construction — no result-buffer gather at all,
    and the carried state is S/N of the res-buffer alternative's VMEM
    (the win that lets data tiles grow). Rows shorter than the tile's
    trip count hold their stack through the EMPTY tail (P1 makes the
    tail contiguous), so preds is simply the final top-of-stack.
    """
    j = pl.program_id(1)
    ops = op_ref[...]  # int32[Pb, N]
    args = arg_ref[...]
    lens = len_ref[...]  # int32[Pb]
    X = x_ref[...]  # f32[F, Db]
    consts = const_ref[...]  # f32[C]
    Pb, N = ops.shape
    Db = X.shape[1]
    S = stack_size

    codes = (list(fn_codes) if fn_codes is not None
             else list(range(_FN_BASE, _FN_BASE + len(prim.FUNCTIONS))))
    bin_codes = [c for c in codes if prim.ARITY[c] == 2]

    def body(t, stack):
        opt = jax.lax.dynamic_index_in_dim(ops, t, 1, keepdims=False)  # [Pb]
        argt = jax.lax.dynamic_index_in_dim(args, t, 1, keepdims=False)

        # terminal value for this instruction
        if gather == "onehot":
            f_iota = jax.lax.broadcasted_iota(jnp.int32, (Pb, n_features), 1)
            onehot = (f_iota == argt[:, None]).astype(jnp.float32)
            feat = jax.lax.dot_general(
                onehot, X, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)  # [Pb, Db]
        else:
            feat = jnp.take(X, jnp.clip(argt, 0, n_features - 1), axis=0)
        c_iota = jax.lax.broadcasted_iota(jnp.int32, (Pb, n_consts), 1)
        cons = jnp.sum((c_iota == argt[:, None]).astype(jnp.float32)
                       * consts[None, :], axis=1)  # [Pb]
        tval = jnp.where((opt == prim.FEATURE)[:, None], feat,
                         jnp.broadcast_to(cons[:, None], (Pb, Db)))

        # function value: operands are the stack's top two slots (rhs =
        # top — postfix emits the right subtree last)
        top, sec = stack[:, 0], stack[:, 1]
        is_bin = jnp.zeros((Pb,), jnp.bool_)
        for c in bin_codes:
            is_bin = is_bin | (opt == c)
        lhs = jnp.where(is_bin[:, None], sec, top)
        fnv = _apply_function_inline(opt[:, None], lhs, top, fn_codes)

        push = jnp.concatenate([tval[:, None], stack[:, :S - 1]], axis=1)
        una = stack.at[:, 0].set(fnv)
        binr = jnp.concatenate([fnv[:, None], stack[:, 2:],
                                jnp.zeros((Pb, 1, Db), jnp.float32)], axis=1)
        is_term = (opt < _FN_BASE)[:, None, None]
        new = jnp.where(is_term, push,
                        jnp.where(is_bin[:, None, None], binr, una))
        # EMPTY tail: hold, so a finished row's result stays on top while
        # longer rows in the tile keep executing
        return jnp.where((opt == prim.EMPTY)[:, None, None], stack, new)

    trip = jnp.max(lens)  # dynamic: sorted tiles of short programs exit early
    stack = jax.lax.fori_loop(0, trip, body,
                              jnp.zeros((Pb, S, Db), jnp.float32))
    preds = stack[:, 0]

    # ---- identical fused moment epilogue to the tree kernel -----------------
    y = y_ref[...]
    wgt = w_ref[...]
    spec = fit.FitnessSpec(kernel, n_classes=n_classes, precision=precision)
    kern = fit.get_kernel(kernel)
    partial = kern.moments(preds, y, wgt, spec)  # [Pb, M]

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        out_ref[...] = kern.merge_moments(out_ref[...], partial, spec)


def eval_fitness_pallas_postfix(op, arg, lens, X, y, weight,
                                const_table, *, stack_size: int,
                                kernel: str = "r",
                                n_classes: int = 3, precision: float = 1e-4,
                                gather: str = "vmem", pop_tile: int = 8,
                                data_tile: int = 1024,
                                interpret: bool | None = None, fn_codes=None):
    """Fused postfix eval+moments over pre-padded inputs.

    op, arg:  int32[P, N]   postfix streams, P % pop_tile == 0
    lens:     int32[P]      active lengths (sort rows by length upstream so
                            tiles of short programs take short fori trips)
    X:        f32[F, D]     D % data_tile == 0
    returns   f32[P, M]     accumulated weighted moments, same contract as
                            eval_fitness_pallas

    `stack_size` is TreeSpec.stack_size (= max_depth + 1), the operand-
    stack bound invariant P5 guarantees. The default gather is "vmem":
    the stack kernel looks up ONE terminal row per instruction, where a
    dynamic take beats the one-hot matmul's F-fold FLOP blowup.
    """
    P, N = op.shape
    F, D = X.shape
    assert P % pop_tile == 0 and D % data_tile == 0, (P, D, pop_tile, data_tile)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_moments = fit.get_kernel(kernel).n_moments

    grid = (P // pop_tile, D // data_tile)
    body = functools.partial(
        _eval_fitness_postfix_kernel, stack_size=stack_size, n_features=F,
        n_consts=const_table.shape[0], kernel=kernel, n_classes=n_classes,
        precision=precision, gather=gather, fn_codes=fn_codes)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((pop_tile, N), lambda i, j: (i, 0)),
            pl.BlockSpec((pop_tile, N), lambda i, j: (i, 0)),
            pl.BlockSpec((pop_tile,), lambda i, j: (i,)),
            pl.BlockSpec((F, data_tile), lambda i, j: (0, j)),
            pl.BlockSpec((data_tile,), lambda i, j: (j,)),
            pl.BlockSpec((data_tile,), lambda i, j: (j,)),
            pl.BlockSpec((const_table.shape[0],), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((pop_tile, n_moments), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((P, n_moments), jnp.float32),
        interpret=interpret,
    )(op, arg, lens, X.astype(jnp.float32), y.astype(jnp.float32),
      weight.astype(jnp.float32), const_table.astype(jnp.float32))


def _fitness_from_subtrees_kernel(root_ref, uniq_ref, y_ref, w_ref, out_ref,
                                  *, kernel: str, n_classes: int,
                                  precision: float):
    """One (pop_tile, data_tile) block of the dedup'd eval: predictions
    are a row-gather from the precomputed unique-subexpression scratch
    (core/eval.evaluate_unique_subtrees), so the per-tree work collapses
    to ONE take plus the fused moment epilogue — the interpreter ran
    once per DISTINCT subtree, not once per tree."""
    j = pl.program_id(1)
    root = root_ref[...]  # int32[Pb]
    uniq = uniq_ref[...]  # f32[U, Db]
    preds = jnp.take(uniq, jnp.clip(root, 0, uniq.shape[0] - 1), axis=0)

    # ---- identical fused moment epilogue to the interpreter kernels --------
    y = y_ref[...]
    wgt = w_ref[...]
    spec = fit.FitnessSpec(kernel, n_classes=n_classes, precision=precision)
    kern = fit.get_kernel(kernel)
    partial = kern.moments(preds, y, wgt, spec)  # [Pb, M]

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        out_ref[...] = kern.merge_moments(out_ref[...], partial, spec)


def eval_fitness_pallas_from_subtrees(root, uniq, y, weight, *,
                                      kernel: str = "r", n_classes: int = 3,
                                      precision: float = 1e-4,
                                      pop_tile: int = 8,
                                      data_tile: int = 1024,
                                      interpret: bool | None = None):
    """Fused gather+moments over precomputed unique-subtree outputs.

    root:  int32[P]     unique-slot id per tree (DedupPlan.root),
                        P % pop_tile == 0
    uniq:  f32[U, D]    unique-subexpression values, D % data_tile == 0
    returns f32[P, M]   accumulated weighted moments — same contract,
                        same (pop, data) grid, same j==0/j!=0 merge
                        order as eval_fitness_pallas_postfix, so moments
                        are BITWISE identical whenever the tile geometry
                        matches the plain kernel's.
    """
    (P,) = root.shape
    U, D = uniq.shape
    assert P % pop_tile == 0 and D % data_tile == 0, (P, D, pop_tile, data_tile)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_moments = fit.get_kernel(kernel).n_moments

    grid = (P // pop_tile, D // data_tile)
    body = functools.partial(
        _fitness_from_subtrees_kernel, kernel=kernel, n_classes=n_classes,
        precision=precision)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((pop_tile,), lambda i, j: (i,)),
            pl.BlockSpec((U, data_tile), lambda i, j: (0, j)),
            pl.BlockSpec((data_tile,), lambda i, j: (j,)),
            pl.BlockSpec((data_tile,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((pop_tile, n_moments), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((P, n_moments), jnp.float32),
        interpret=interpret,
    )(root, uniq.astype(jnp.float32), y.astype(jnp.float32),
      weight.astype(jnp.float32))


def _fitness_from_preds_kernel(preds_ref, y_ref, w_ref, out_ref, *,
                               kernel: str, n_classes: int, precision: float):
    """One (pop_tile, data_tile) block of the spilled dedup epilogue:
    predictions were gathered from the unique-subtree table at the XLA
    level (HBM-resident `uniq[root]`), so the block only streams its own
    pop_tile rows — no U-row scratch in VMEM."""
    j = pl.program_id(1)
    preds = preds_ref[...]  # f32[Pb, Db]

    # ---- identical fused moment epilogue to the interpreter kernels --------
    y = y_ref[...]
    wgt = w_ref[...]
    spec = fit.FitnessSpec(kernel, n_classes=n_classes, precision=precision)
    kern = fit.get_kernel(kernel)
    partial = kern.moments(preds, y, wgt, spec)  # [Pb, M]

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        out_ref[...] = kern.merge_moments(out_ref[...], partial, spec)


def eval_fitness_pallas_from_preds(preds, y, weight, *, kernel: str = "r",
                                   n_classes: int = 3, precision: float = 1e-4,
                                   pop_tile: int = 8, data_tile: int = 1024,
                                   interpret: bool | None = None):
    """Fused moments over pre-gathered predictions.

    preds:  f32[P, D]   per-tree predictions (`uniq[DedupPlan.root]`
                        materialized at the XLA level), P % pop_tile == 0,
                        D % data_tile == 0
    returns f32[P, M]   accumulated weighted moments — same contract,
                        same (pop, data) grid, same j==0/j!=0 merge order
                        as eval_fitness_pallas_postfix, so moments are
                        BITWISE identical at the same tile geometry.

    This is the dedup spill path: when the f32[U, Db] unique-subtree
    scratch of `eval_fitness_pallas_from_subtrees` would not fit VMEM at
    the plain kernel's tile pick, `ops._moments_padded` gathers in HBM
    and streams (pop_tile, data_tile) blocks here instead of shrinking
    the data tile — shrinking would change the merge order and break the
    dedup-off/dedup-on bitwise contract.
    """
    P, D = preds.shape
    assert P % pop_tile == 0 and D % data_tile == 0, (P, D, pop_tile, data_tile)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_moments = fit.get_kernel(kernel).n_moments

    grid = (P // pop_tile, D // data_tile)
    body = functools.partial(
        _fitness_from_preds_kernel, kernel=kernel, n_classes=n_classes,
        precision=precision)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((pop_tile, data_tile), lambda i, j: (i, j)),
            pl.BlockSpec((data_tile,), lambda i, j: (j,)),
            pl.BlockSpec((data_tile,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((pop_tile, n_moments), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((P, n_moments), jnp.float32),
        interpret=interpret,
    )(preds.astype(jnp.float32), y.astype(jnp.float32),
      weight.astype(jnp.float32))


def eval_fitness_pallas(op, arg, X, y, weight, const_table, *, max_depth: int,
                        kernel: str = "r", n_classes: int = 3, precision: float = 1e-4,
                        gather: str = "onehot", pop_tile: int = 8, data_tile: int = 1024,
                        interpret: bool | None = None, fn_codes=None):
    """Fused eval+moments over pre-padded inputs.

    op, arg:  int32[P, N]   P % pop_tile == 0
    X:        f32[F, D]     D % data_tile == 0
    y, weight f32[D]        weight is 1.0 on valid points, 0.0 on padding —
                            both the wrapper's tile padding AND any dataset
                            padding the caller threaded in (loader.pad_rows),
                            composed upstream in ops.fitness
    returns   f32[P, M]     the kernel's fully-accumulated weighted moments
                            (M = FitnessKernel.n_moments; for decomposable
                            kernels M == 1 and [:, 0] is the fitness);
                            finalize with FitnessKernel.reduce_moments
    """
    P, N = op.shape
    F, D = X.shape
    assert P % pop_tile == 0 and D % data_tile == 0, (P, D, pop_tile, data_tile)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_moments = fit.get_kernel(kernel).n_moments

    grid = (P // pop_tile, D // data_tile)
    body = functools.partial(
        _eval_fitness_kernel, max_depth=max_depth, n_features=F,
        n_consts=const_table.shape[0], kernel=kernel, n_classes=n_classes,
        precision=precision, gather=gather, fn_codes=fn_codes)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((pop_tile, N), lambda i, j: (i, 0)),
            pl.BlockSpec((pop_tile, N), lambda i, j: (i, 0)),
            pl.BlockSpec((F, data_tile), lambda i, j: (0, j)),
            pl.BlockSpec((data_tile,), lambda i, j: (j,)),
            pl.BlockSpec((data_tile,), lambda i, j: (j,)),
            pl.BlockSpec((const_table.shape[0],), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((pop_tile, n_moments), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((P, n_moments), jnp.float32),
        interpret=interpret,
    )(op, arg, X.astype(jnp.float32), y.astype(jnp.float32),
      weight.astype(jnp.float32), const_table.astype(jnp.float32))
