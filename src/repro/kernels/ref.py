"""Pure-jnp oracle for the fused GP eval+fitness kernel.

Numerically identical contract to kernels/ops.fitness (same padding/
weighting semantics) but built from the reference evaluator — the HBM-
streaming path the kernel is measured against.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.eval import evaluate_population
from repro.core.fitness import FitnessSpec
from repro.core.trees import TreeSpec


def fitness_ref(op, arg, X, y, const_table, tree_spec: TreeSpec, fit_spec: FitnessSpec,
                weight=None):
    """f32[P] fitness (minimize); weight masks out padded data points.
    The reduction itself is the registered FitnessKernel's — this function
    only supplies the reference evaluator's predictions."""
    preds = evaluate_population(op, arg, X, const_table, tree_spec)  # [P, D]
    from repro.core.fitness import fitness_from_preds

    return fitness_from_preds(preds, y, fit_spec, weight=weight)


def fitness_ref_tiled(op, arg, X, y, const_table, tree_spec: TreeSpec,
                      fit_spec: FitnessSpec, weight=None, tile: int = 65536):
    """Same contract, but scans the data dimension in tiles so the
    [pop, nodes, data] evaluation buffer never exceeds one tile — the jnp
    analogue of the Pallas kernel's VMEM tiling. A caller-supplied `weight`
    (dataset padding mask, weight 0 on padded points) composes with the
    internal tile-padding mask. Kernels that are not sum-decomposable over
    data (FitnessKernel.decomposable=False) fall back to the un-tiled
    path."""
    import jax

    from repro.core.fitness import get_kernel

    D = X.shape[1]
    if D <= tile or not get_kernel(fit_spec.kernel).decomposable:
        return fitness_ref(op, arg, X, y, const_table, tree_spec, fit_spec,
                           weight=weight)
    pad = (-D) % tile
    w = jnp.ones((D,), jnp.float32) if weight is None else weight.astype(jnp.float32)
    if pad:
        X = jnp.pad(X, ((0, 0), (0, pad)))
        y = jnp.pad(y, (0, pad))
        w = jnp.pad(w, (0, pad))
    n = (D + pad) // tile
    Xs = X.reshape(X.shape[0], n, tile).transpose(1, 0, 2)
    ys = y.reshape(n, tile)
    ws = w.reshape(n, tile)

    def body(acc, inp):
        Xt, yt, wt = inp
        return acc + fitness_ref(op, arg, Xt, yt, const_table, tree_spec, fit_spec,
                                 weight=wt), None

    out, _ = jax.lax.scan(body, jnp.zeros((op.shape[0],), jnp.float32),
                          (Xs, ys, ws))
    return out
