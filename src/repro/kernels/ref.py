"""Pure-jnp oracle for the fused GP eval+fitness kernel.

Numerically identical contract to kernels/ops.fitness (same padding/
weighting semantics) but built from the reference evaluator — the HBM-
streaming path the kernel is measured against. Both the finalized
fitness and the phase-1 moment pass (`moments_ref*`, what the mesh step
psums across the data axis) are exposed.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.eval import evaluate_population
from repro.core.fitness import FitnessSpec
from repro.core.trees import TreeSpec


def fitness_ref(op, arg, X, y, const_table, tree_spec: TreeSpec, fit_spec: FitnessSpec,
                weight=None):
    """f32[P] fitness (minimize); weight masks out padded data points.
    The reduction itself is the registered FitnessKernel's — this function
    only supplies the reference evaluator's predictions."""
    preds = evaluate_population(op, arg, X, const_table, tree_spec)  # [P, D]
    from repro.core.fitness import fitness_from_preds

    return fitness_from_preds(preds, y, fit_spec, weight=weight)


def moments_ref(op, arg, X, y, const_table, tree_spec: TreeSpec, fit_spec: FitnessSpec,
                weight=None):
    """Phase 1 of the two-pass protocol on the reference evaluator:
    f32[P, M] weighted moment partials of the population against
    (X:[F,D], y:[D]). Partials from different data tiles/shards sum
    element-wise; `FitnessKernel.reduce_moments` finalizes."""
    preds = evaluate_population(op, arg, X, const_table, tree_spec)  # [P, D]
    from repro.core.fitness import moments_from_preds

    return moments_from_preds(preds, y, fit_spec, weight=weight)


def moments_ref_tiled(op, arg, X, y, const_table, tree_spec: TreeSpec,
                      fit_spec: FitnessSpec, weight=None, tile: int = 65536):
    """`moments_ref`, scanning the data dimension in tiles so the
    [pop, nodes, data] evaluation buffer never exceeds one tile — the jnp
    analogue of the Pallas kernel's VMEM tiling. Tile partials merge via
    the kernel's `merge_moments` (elementwise sum, or the kernel's
    pairwise combine — e.g. pearson/r2's Chan merge of centered
    moments; the all-zeros init is a merge identity by contract). A
    caller-supplied `weight` (dataset padding mask, weight 0 on padded
    points) composes with the internal tile-padding mask; moments of
    zero-weight points are exact zeros, so tiling never changes the
    result."""
    import jax

    from repro.core.fitness import get_kernel

    kern = get_kernel(fit_spec.kernel)
    D = X.shape[1]
    if D <= tile:
        return moments_ref(op, arg, X, y, const_table, tree_spec, fit_spec,
                           weight=weight)
    pad = (-D) % tile
    w = jnp.ones((D,), jnp.float32) if weight is None else weight.astype(jnp.float32)
    if pad:
        X = jnp.pad(X, ((0, 0), (0, pad)))
        y = jnp.pad(y, (0, pad))
        w = jnp.pad(w, (0, pad))
    n = (D + pad) // tile
    Xs = X.reshape(X.shape[0], n, tile).transpose(1, 0, 2)
    ys = y.reshape(n, tile)
    ws = w.reshape(n, tile)

    def body(acc, inp):
        Xt, yt, wt = inp
        part = moments_ref(op, arg, Xt, yt, const_table, tree_spec,
                           fit_spec, weight=wt)
        return kern.merge_moments(acc, part, fit_spec), None

    out, _ = jax.lax.scan(
        body, jnp.zeros((op.shape[0], kern.n_moments), jnp.float32), (Xs, ys, ws))
    return out


def fitness_ref_tiled(op, arg, X, y, const_table, tree_spec: TreeSpec,
                      fit_spec: FitnessSpec, weight=None, tile: int = 65536):
    """Same contract as `fitness_ref`, tiled over data: accumulate the
    kernel's moment partials per tile, then finalize once — so EVERY
    registered kernel tiles, including two-pass objectives (pearson, r2)
    whose statistics need the whole dataset. Kernels registered without a
    moment pass (legacy decomposable=False objectives) fall back to the
    un-tiled path."""
    from repro.core.fitness import get_kernel

    kern = get_kernel(fit_spec.kernel)
    if X.shape[1] <= tile or kern.moments is None:
        return fitness_ref(op, arg, X, y, const_table, tree_spec, fit_spec,
                           weight=weight)
    m = moments_ref_tiled(op, arg, X, y, const_table, tree_spec, fit_spec,
                          weight=weight, tile=tile)
    return kern.reduce_moments(m, fit_spec)
