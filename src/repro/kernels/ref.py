"""Pure-jnp oracle for the fused GP eval+fitness kernel.

Numerically identical contract to kernels/ops.fitness (same padding/
weighting semantics) but built from the reference evaluator — the HBM-
streaming path the kernel is measured against. Both the finalized
fitness and the phase-1 moment pass (`moments_ref*`, what the mesh step
psums across the data axis) are exposed.

Every entry point takes `dedup`/`dedup_cap`: any value other than
``"off"`` engages the exact-tier population-wide subexpression dedup
(core/eval.make_postfix_evaluator) for postfix genomes — each distinct
subtree evaluated once per call, predictions (and therefore moments and
fitness) BITWISE identical to dedup-off. Non-postfix genomes ignore the
flag. The dedup plan is built once per call and shared by every data
tile of the tiled paths.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.eval import make_postfix_evaluator
from repro.core.fitness import FitnessSpec
from repro.core.trees import TreeSpec


def fitness_ref(op, arg, X, y, const_table, tree_spec: TreeSpec, fit_spec: FitnessSpec,
                weight=None, dedup: str = "off", dedup_cap: int = 0):
    """f32[P] fitness (minimize); weight masks out padded data points.
    The reduction itself is the registered FitnessKernel's — this function
    only supplies the reference evaluator's predictions."""
    ev = make_postfix_evaluator(op, arg, const_table, tree_spec,
                                dedup=dedup, dedup_cap=dedup_cap)
    preds = ev(X)  # [P, D]
    from repro.core.fitness import fitness_from_preds

    return fitness_from_preds(preds, y, fit_spec, weight=weight)


def moments_ref(op, arg, X, y, const_table, tree_spec: TreeSpec, fit_spec: FitnessSpec,
                weight=None, dedup: str = "off", dedup_cap: int = 0,
                _evaluator=None):
    """Phase 1 of the two-pass protocol on the reference evaluator:
    f32[P, M] weighted moment partials of the population against
    (X:[F,D], y:[D]). Partials from different data tiles/shards sum
    element-wise; `FitnessKernel.reduce_moments` finalizes."""
    ev = _evaluator if _evaluator is not None else make_postfix_evaluator(
        op, arg, const_table, tree_spec, dedup=dedup, dedup_cap=dedup_cap)
    preds = ev(X)  # [P, D]
    from repro.core.fitness import moments_from_preds

    return moments_from_preds(preds, y, fit_spec, weight=weight)


def moments_ref_tiled(op, arg, X, y, const_table, tree_spec: TreeSpec,
                      fit_spec: FitnessSpec, weight=None, tile: int = 65536,
                      dedup: str = "off", dedup_cap: int = 0):
    """`moments_ref`, scanning the data dimension in tiles so the
    [pop, nodes, data] evaluation buffer never exceeds one tile — the jnp
    analogue of the Pallas kernel's VMEM tiling. Tile partials merge via
    the kernel's `merge_moments` (elementwise sum, or the kernel's
    pairwise combine — e.g. pearson/r2's Chan merge of centered
    moments; the all-zeros init is a merge identity by contract). A
    caller-supplied `weight` (dataset padding mask, weight 0 on padded
    points) composes with the internal tile-padding mask; moments of
    zero-weight points are exact zeros, so tiling never changes the
    result. The dedup plan (when engaged) is built once, outside the
    tile scan — it depends only on the genomes, not the data."""
    import jax

    from repro.core.fitness import get_kernel

    kern = get_kernel(fit_spec.kernel)
    ev = make_postfix_evaluator(op, arg, const_table, tree_spec,
                                dedup=dedup, dedup_cap=dedup_cap)
    D = X.shape[1]
    if D <= tile:
        return moments_ref(op, arg, X, y, const_table, tree_spec, fit_spec,
                           weight=weight, _evaluator=ev)
    pad = (-D) % tile
    w = jnp.ones((D,), jnp.float32) if weight is None else weight.astype(jnp.float32)
    if pad:
        X = jnp.pad(X, ((0, 0), (0, pad)))
        y = jnp.pad(y, (0, pad))
        w = jnp.pad(w, (0, pad))
    n = (D + pad) // tile
    Xs = X.reshape(X.shape[0], n, tile).transpose(1, 0, 2)
    ys = y.reshape(n, tile)
    ws = w.reshape(n, tile)

    def body(acc, inp):
        Xt, yt, wt = inp
        part = moments_ref(op, arg, Xt, yt, const_table, tree_spec,
                           fit_spec, weight=wt, _evaluator=ev)
        return kern.merge_moments(acc, part, fit_spec), None

    out, _ = jax.lax.scan(
        body, jnp.zeros((op.shape[0], kern.n_moments), jnp.float32), (Xs, ys, ws))
    return out


def fitness_ref_tiled(op, arg, X, y, const_table, tree_spec: TreeSpec,
                      fit_spec: FitnessSpec, weight=None, tile: int = 65536,
                      dedup: str = "off", dedup_cap: int = 0):
    """Same contract as `fitness_ref`, tiled over data: accumulate the
    kernel's moment partials per tile, then finalize once — so EVERY
    registered kernel tiles, including two-pass objectives (pearson, r2)
    whose statistics need the whole dataset. Kernels registered without a
    moment pass (legacy decomposable=False objectives) fall back to the
    un-tiled path."""
    from repro.core.fitness import get_kernel

    kern = get_kernel(fit_spec.kernel)
    if X.shape[1] <= tile or kern.moments is None:
        return fitness_ref(op, arg, X, y, const_table, tree_spec, fit_spec,
                           weight=weight, dedup=dedup, dedup_cap=dedup_cap)
    m = moments_ref_tiled(op, arg, X, y, const_table, tree_spec, fit_spec,
                          weight=weight, tile=tile, dedup=dedup,
                          dedup_cap=dedup_cap)
    return kern.reduce_moments(m, fit_spec)
