"""Multi-host cluster bring-up: jax.distributed + mesh construction from
the environment, with the coordinator/worker conventions a TPU pod (or
SLURM/GKE job) provides.

On a real deployment every host runs the SAME entrypoint:

    python -m repro.launch.train --arch ... --cluster

and this module (a) initializes `jax.distributed` from environment
variables (COORDINATOR_ADDRESS/NUM_PROCESSES/PROCESS_ID, or their
SLURM/TPU-metadata equivalents that jax auto-detects), (b) builds the
production mesh over the global device set, and (c) returns per-process
data-sharding info so hosts feed disjoint batch slices.

This container is single-process; `init_cluster()` degrades to a no-op
single-process "cluster" (tests exercise the env parsing and slicing
logic directly), and the same code path runs unmodified under a real
multi-host job — the standard jax SPMD contract.
"""
from __future__ import annotations

import dataclasses
import os

import jax

from repro.launch.mesh import make_production_mesh


@dataclasses.dataclass(frozen=True)
class ClusterInfo:
    num_processes: int
    process_id: int
    coordinator: str | None

    @property
    def is_coordinator(self) -> bool:
        return self.process_id == 0


def cluster_env(environ=None) -> ClusterInfo:
    """Parse the launch environment (explicit vars > SLURM > single)."""
    env = environ if environ is not None else os.environ
    if "COORDINATOR_ADDRESS" in env:
        return ClusterInfo(int(env.get("NUM_PROCESSES", "1")),
                           int(env.get("PROCESS_ID", "0")),
                           env["COORDINATOR_ADDRESS"])
    if "SLURM_NTASKS" in env and int(env["SLURM_NTASKS"]) > 1:
        nodelist = env.get("SLURM_STEP_NODELIST", env.get("SLURM_NODELIST", ""))
        head = nodelist.split(",")[0].replace("[", "").split("-")[0]
        return ClusterInfo(int(env["SLURM_NTASKS"]),
                           int(env.get("SLURM_PROCID", "0")),
                           f"{head}:12345" if head else None)
    return ClusterInfo(1, 0, None)


def init_cluster(info: ClusterInfo | None = None) -> ClusterInfo:
    """Initialize jax.distributed when the env says we're multi-process."""
    info = info or cluster_env()
    if info.num_processes > 1:
        jax.distributed.initialize(
            coordinator_address=info.coordinator,
            num_processes=info.num_processes,
            process_id=info.process_id)
    return info


def host_batch_slice(global_batch: int, info: ClusterInfo) -> slice:
    """Disjoint per-host slice of the global batch (data loaders feed only
    addressable shards; jax.make_array_from_process_local_data assembles)."""
    if global_batch % info.num_processes:
        raise ValueError(f"global batch {global_batch} % hosts "
                         f"{info.num_processes} != 0")
    per = global_batch // info.num_processes
    return slice(info.process_id * per, (info.process_id + 1) * per)


def cluster_mesh(*, multi_pod: bool | None = None):
    """Production mesh over the global device view. multi_pod defaults to
    whether the job spans more than 256 chips."""
    n = len(jax.devices())
    if multi_pod is None:
        multi_pod = n > 256
    return make_production_mesh(multi_pod=multi_pod)
