import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
# cell with ShapeDtypeStruct inputs (zero allocation), record
# memory_analysis / cost_analysis / per-collective bytes for §Roofline.
#
# MUST be invoked as its own process (the XLA_FLAGS line above runs before
# any other import, including jax) — never import this module from a
# process that already initialized jax with 1 device.
#
# Usage:
#     PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-32b \
#         --shape train_4k [--multi-pod] [--out artifacts/dryrun]
#     PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
#     PYTHONPATH=src python -m repro.launch.dryrun --gp karoo-kat7-pod

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import all_arch_names, get_config
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.launch import sharding as SH
from repro.models import model as Md
from repro.models.transformer import ShardingPolicy
from repro.optim.adamw import for_config

# ---------------------------------------------------------------------------
# HLO collective accounting
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1}

_COLL_RE = re.compile(
    r"=\s+(?P<types>[^=]*?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\(", re.X)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(types: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(types):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by collectives, summed per op kind.

    Shapes in post-partitioning HLO are per-device. We count the RESULT
    shape of each collective (for all-gather that is the gathered size ≈
    wire bytes × n/(n-1); for reduce-scatter the input is n× larger than
    the wire volume — we count the result, a lower bound; all-reduce wire
    cost is ~2× its size on a ring — recorded raw here, modeled in
    benchmarks/roofline.py)."""
    out: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        op = m.group("op")
        out[op] = out.get(op, 0) + _type_bytes(m.group("types"))
    return out


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------


def make_policy(mesh) -> ShardingPolicy:
    dp = 1
    for a in batch_axes(mesh):
        dp *= mesh.shape[a]
    return ShardingPolicy(batch=batch_axes(mesh), model="model",
                          tp_size=mesh.shape["model"], dp_size=dp)


def lower_cell(cfg, shape_name: str, mesh):
    """Returns the `jax.stages.Lowered` for one (arch × shape × mesh) cell."""
    cfg = cfg.with_policy(make_policy(mesh))
    kind, specs = Md.input_specs(cfg, shape_name)

    if kind == "train":
        opt = for_config(cfg)

        def init_state(key):
            params = Md.init_params(cfg, key)
            return {"params": params, "opt": opt.init(params),
                    "step": jnp.zeros((), jnp.int32)}

        state_shapes = jax.eval_shape(init_state, jax.random.PRNGKey(0))
        state_specs = SH.train_state_specs(cfg, state_shapes, mesh)
        state_sds = SH.named(mesh, state_specs, state_shapes)
        batch_sds = SH.named(mesh, SH.batch_specs(cfg, specs), specs)
        step = Md.make_train_step(cfg, opt, param_specs=state_specs["params"])
        with compat.set_mesh(mesh):
            metric_shapes = jax.eval_shape(step, state_shapes, specs)[1]
            out_shardings = (
                jax.tree.map(lambda s: SH.NamedSharding(mesh, s), state_specs),
                jax.tree.map(lambda _: SH.NamedSharding(mesh, SH.P()), metric_shapes))
            return jax.jit(step, donate_argnums=(0,),
                           out_shardings=out_shardings).lower(state_sds, batch_sds)

    params_shapes = jax.eval_shape(lambda k: Md.init_params(cfg, k), jax.random.PRNGKey(0))
    params_sds = SH.named(mesh, SH.param_specs(cfg, params_shapes, mesh), params_shapes)
    b_axes = tuple(cfg.policy.batch)
    logits_spec = (SH.P(b_axes, None, "model")
                   if cfg.vocab % (mesh.shape["model"]) == 0 else SH.P(b_axes, None, None))

    if kind == "prefill":
        batch_sds = SH.named(mesh, SH.batch_specs(cfg, specs), specs)
        S = Md.SHAPES[shape_name]["seq"]
        cache_shapes = jax.eval_shape(lambda: Md.init_cache(cfg, Md.SHAPES[shape_name]["batch"], S))
        cache_out = SH.cache_specs(cfg, cache_shapes, mesh, seq_shard=False)

        def prefill_fn(p, b):
            return Md.prefill(cfg, p, b, max_len=S)

        with compat.set_mesh(mesh):
            out_shardings = (SH.NamedSharding(mesh, logits_spec),
                             jax.tree.map(lambda s: SH.NamedSharding(mesh, s), cache_out))
            return jax.jit(prefill_fn, out_shardings=out_shardings).lower(
                params_sds, batch_sds)

    # decode
    seq_shard = Md.SHAPES[shape_name]["batch"] == 1  # long-context: CP over seq
    cache_out_specs = SH.cache_specs(cfg, specs["cache"], mesh, seq_shard=seq_shard)
    cache_sds = SH.named(mesh, cache_out_specs, specs["cache"])
    tok_sds = SH.named(mesh, jax.tree.map(lambda _: SH.P(b_axes, None)
                                          if not seq_shard else SH.P(None, None),
                                          specs["token"]), specs["token"])
    len_sds = specs["cur_len"]
    step = Md.make_serve_step(cfg)
    with compat.set_mesh(mesh):
        # pinning cache out_shardings == in_shardings lets donation alias the
        # cache buffers (decode must be in-place at 100+ GB caches)
        long_logits = (SH.P(None, None, "model")
                       if cfg.vocab % mesh.shape["model"] == 0 else SH.P(None, None, None))
        out_shardings = (
            SH.NamedSharding(mesh, logits_spec if not seq_shard else long_logits),
            jax.tree.map(lambda s: SH.NamedSharding(mesh, s), cache_out_specs))
        return jax.jit(step, donate_argnums=(1,), out_shardings=out_shardings).lower(
            params_sds, cache_sds, tok_sds, len_sds)


def analyze(lowered, *, want_hlo: bool = False) -> dict:
    t0 = time.time()
    compiled = lowered.compile()
    dt = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compat.cost_analysis(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    rec = {
        "compile_s": round(dt, 1),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": coll,
        "memory": {
            "argument_gb": mem.argument_size_in_bytes / 2**30,
            "output_gb": mem.output_size_in_bytes / 2**30,
            "temp_gb": mem.temp_size_in_bytes / 2**30,
            "alias_gb": mem.alias_size_in_bytes / 2**30,
            "code_mb": mem.generated_code_size_in_bytes / 2**20,
        },
    }
    if want_hlo:
        rec["hlo"] = hlo
    return rec


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             keep_hlo: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    if not Md.shape_supported(cfg, shape_name):
        rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
               "status": "skip:full-attn"}
    else:
        try:
            lowered = lower_cell(cfg, shape_name, mesh)
            rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                   "status": "ok", **analyze(lowered, want_hlo=keep_hlo)}
        except Exception as e:  # a failure here is a bug in the system
            rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                   "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-4000:]}
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = "mp" if multi_pod else "sp"
        hlo = rec.pop("hlo", None)
        path = os.path.join(out_dir, f"{arch}_{shape_name}_{tag}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if hlo is not None:
            with open(path.replace(".json", ".hlo.txt"), "w") as f:
                f.write(hlo)
    return rec


# ---------------------------------------------------------------------------
# GP (paper-workload) cells
# ---------------------------------------------------------------------------

GP_CELLS = {
    # name: (pop, n_features, rows, kernel)  — production-scale Karoo runs
    "karoo-kat7-pod": (4096, 9, 4_194_304, "c"),
    "karoo-ligo-pod": (1024, 1373, 524_288, "c"),
    "karoo-kepler-pod": (8192, 2, 1_048_576, "r"),
}


def run_gp_cell(name: str, multi_pod: bool, out_dir: str, keep_hlo: bool = False,
                eval_impl: str = "jnp", block_steps: int = 10) -> dict:
    """Lower one production GP cell as a K-generation evolution block —
    the scan-inside-shard_map program `GPSession.evolve()` dispatches, so
    the cost/memory record covers the real device-resident loop surface
    (collectives included), not a single step."""
    from repro.core import GPState
    from repro.core.engine import cache_width
    from repro.gp import GPSession

    pop, F, rows, kern = GP_CELLS[name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    sess = GPSession(name=name, pop_size=pop, max_depth=5, n_features=F,
                     n_consts=8, kernel=kern, backend=eval_impl, topology=mesh)
    cfg = sess.config
    spec = cfg.tree_spec
    block, specs = sess.build_sharded_block(block_steps)
    N = spec.num_nodes
    E = cache_width(cfg)
    sds = jax.ShapeDtypeStruct
    state_shapes = GPState(
        key=sds((2,), jnp.uint32), op=sds((pop, N), jnp.int32),
        arg=sds((pop, N), jnp.int32), fitness=sds((pop,), jnp.float32),
        best_op=sds((N,), jnp.int32), best_arg=sds((N,), jnp.int32),
        best_fitness=sds((), jnp.float32), generation=sds((), jnp.int32),
        cache_op=sds((E, N), jnp.int32), cache_arg=sds((E, N), jnp.int32),
        cache_fit=sds((E,), jnp.float32))
    state_sds = SH.named(mesh, specs["state"], state_shapes)
    X_sds = SH.named(mesh, specs["X"], sds((F, rows), jnp.float32))
    y_sds = SH.named(mesh, specs["y"], sds((rows,), jnp.float32))
    w_sds = SH.named(mesh, specs["weight"], sds((rows,), jnp.float32))
    limit_sds = SH.named(mesh, specs["limit"], sds((), jnp.int32))
    try:
        with compat.set_mesh(mesh):
            lowered = jax.jit(block, donate_argnums=(0,)).lower(
                state_sds, X_sds, y_sds, w_sds, limit_sds)
        rec = {"arch": name, "shape": f"pop{pop}_rows{rows}_F{F}_K{block_steps}",
               "multi_pod": multi_pod, "status": "ok",
               **analyze(lowered, want_hlo=keep_hlo)}
    except Exception as e:
        rec = {"arch": name, "multi_pod": multi_pod, "status": "FAIL",
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-4000:]}
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = "mp" if multi_pod else "sp"
        hlo = rec.pop("hlo", None)
        path = os.path.join(out_dir, f"{name}_{tag}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if hlo is not None:
            with open(path.replace(".json", ".hlo.txt"), "w") as f:
                f.write(hlo)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--gp")
    ap.add_argument("--gp-impl", default="jnp")
    ap.add_argument("--gp-block", type=int, default=10,
                    help="generations per lowered GP evolution block")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    args = ap.parse_args()

    if args.gp:
        rec = run_gp_cell(args.gp, args.multi_pod, args.out, args.keep_hlo,
                          args.gp_impl, block_steps=args.gp_block)
        print(json.dumps({k: v for k, v in rec.items() if k != "trace"}, indent=1))
        raise SystemExit(0 if rec["status"] != "FAIL" else 1)

    cells = ([(args.arch, args.shape)] if args.arch and args.shape else
             [(a, s) for a in all_arch_names() for s in Md.SHAPES])
    if not args.all and not (args.arch and args.shape):
        ap.error("need --arch+--shape, --gp, or --all")
    failures = 0
    for arch, shape in cells:
        rec = run_cell(arch, shape, args.multi_pod, args.out, args.keep_hlo)
        line = {k: rec.get(k) for k in ("arch", "shape", "status", "compile_s",
                                        "flops", "error")}
        print(json.dumps(line))
        failures += rec["status"] == "FAIL"
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
