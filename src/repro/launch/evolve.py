"""GP evolution driver — the paper's workload, end to end, via repro.gp.

Mirrors Karoo GP's server interface (§2.2 "scriptable runs via
command-line arguments") and its per-generation archiving (fx_archive_):

    PYTHONPATH=src python -m repro.launch.evolve --dataset kepler \
        --generations 30 --pop 100 --backend pallas --archive /tmp/karoo

Mesh/island runs ride the same door (requires that many local devices,
e.g. under --xla_force_host_platform_device_count):

    ... --mesh data=2,model=2,pod=2

Island-model runs work on ANY of the above — one device or a mesh
(pods × in-device islands when both are present):

    ... --islands 4 --migrate-every 5 --migrate-k 2 --island-topology torus
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.data.datasets import BY_NAME
from repro.gp import GPSession, MeshTopology


def parse_mesh(spec: str | None) -> MeshTopology | None:
    """'data=2,model=2[,pod=2]' → MeshTopology."""
    if not spec:
        return None
    kw = {}
    for part in spec.split(","):
        k, _, v = part.partition("=")
        kw[k.strip()] = int(v)
    return MeshTopology(**kw)


def run_dataset(name: str, *, generations: int = 30, pop: int = 100,
                depth: int = 5, backend: str = "jnp", fn_set: str = "auto",
                topology: MeshTopology | None = None,
                archive: str | None = None, seed: int = 0, log=print,
                ckpt_dir: str | None = None, ckpt_every: int = 10,
                seeds=None, archive_every: int = 1, islands: int = 1,
                migrate_every: int = 10, migrate_k: int = 4,
                island_topology: str = "ring", chunk_rows: int | None = None,
                trace: str | None = None, metrics: str | None = None,
                profile_dir: str | None = None, profile_block: int | None = None):
    """One archived GP run on a named dataset through the GPSession door.

    `archive_every` is the callback (= evolution-block) period: the run
    stays device-resident for that many generations per dispatch, and the
    archive gets one record per block boundary (the per-generation
    best-fitness curve still lands in full via `sess.history`).
    `islands > 1` runs the island-model layout — `pop` trees PER island —
    on whatever topology the run uses (docs/islands.md). `trace` /
    `metrics` are output paths arming the repro.obs Tracer (Chrome trace
    JSON — open in Perfetto) and Metrics JSONL sink
    (docs/observability.md); `profile_dir`/`profile_block` arm a
    jax.profiler window around one evolution block."""
    from repro.obs import Metrics, Tracer

    tracer = (Tracer(trace, profile_dir=profile_dir,
                     profile_block=profile_block)
              if (trace or profile_dir) else None)
    mreg = Metrics(metrics) if metrics else None
    kw = dict(pop_size=pop, max_depth=depth, n_consts=8, generations=generations,
              backend=backend, topology=topology,
              checkpoint_dir=ckpt_dir, checkpoint_every=ckpt_every,
              islands=islands, migrate_every=migrate_every, migrate_k=migrate_k,
              island_topology=island_topology, chunk_rows=chunk_rows,
              tracer=tracer, metrics=mreg)
    if fn_set != "auto":
        kw["fn_set"] = fn_set
    history = []

    def archive_gen(_, state):
        g = int(state.generation) - 1  # absolute index, stable across resumes
        best = float(np.min(state.best_fitness))  # min across islands
        # full per-generation curve from the block's metrics stream
        history.extend(sess.history[len(history):])
        if archive:
            os.makedirs(archive, exist_ok=True)
            rec = {"generation": g, "best_fitness": best,
                   "best_tree": sess.best_expression(),
                   "population_fitness": np.asarray(state.fitness).tolist()}
            with open(os.path.join(archive, f"gen_{g:04d}.json"), "w") as f:
                json.dump(rec, f)
        if g % 5 < archive_every or g == generations - 1:
            log(f"gen {g:3d} best_fitness {best:.5f}")

    sess = GPSession.from_dataset(name, callback=archive_gen,
                                  callback_every=archive_every, **kw)
    sess.init(key=jax.random.PRNGKey(seed), seeds=seeds)
    if sess.generation:
        log(f"resumed from generation {sess.generation}")
    t0 = time.time()
    sess.evolve(max(0, generations - sess.generation))
    wall = time.time() - t0
    history.extend(sess.history[len(history):])
    tree = sess.best_expression()
    log(f"[{name}] {generations} generations in {wall:.2f}s — best: {tree} "
        f"({sess.stats['blocks']} blocks, {sess.stats['host_syncs']} host syncs)")
    if sess.stats["cache_queries"]:
        log(f"  elite cache: {sess.stats['cache_hits']}/"
            f"{sess.stats['cache_queries']} hits "
            f"({sess.stats['cache_hit_rate']:.2f})")
    if tracer is not None and trace:
        log(f"  trace written to {tracer.save()}")
    if mreg is not None:
        mreg.close()
        log(f"  metrics written to {metrics} "
            f"(summarize: python -m repro.obs.report {metrics})")
    return sess.state, wall, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="kepler", choices=sorted(BY_NAME))
    ap.add_argument("--generations", type=int, default=30)
    ap.add_argument("--pop", type=int, default=100)
    ap.add_argument("--depth", type=int, default=5)
    ap.add_argument("--backend", "--impl", dest="backend", default="jnp",
                    help="eval backend: scalar | jnp | pallas | auto")
    ap.add_argument("--mesh", default=None,
                    help="mesh topology, e.g. data=2,model=2,pod=2")
    ap.add_argument("--archive", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed-exprs", nargs="*", default=None,
                    help="seed population expressions, e.g. '(x0 * x1)'")
    ap.add_argument("--archive-every", type=int, default=1,
                    help="generations per evolution block / archive record "
                         "(larger = fewer host syncs)")
    ap.add_argument("--islands", type=int, default=1,
                    help="island-model layout: islands of --pop trees each "
                         "(works single-device and on any --mesh; with a pod "
                         "axis, islands spread over pods)")
    ap.add_argument("--migrate-every", type=int, default=10,
                    help="generations between island migration events")
    ap.add_argument("--migrate-k", type=int, default=4,
                    help="elites exchanged per migration event")
    ap.add_argument("--island-topology", default="ring",
                    choices=["ring", "torus", "broadcast-best"],
                    help="migration routing between islands")
    ap.add_argument("--chunk-rows", type=int, default=None,
                    help="streaming chunked fitness: evaluate the dataset as "
                         "a fold over fixed-size chunks (bounded device "
                         "memory; None = monolithic)")
    ap.add_argument("--trace", default=None,
                    help="write a Chrome trace JSON (open in Perfetto / "
                         "chrome://tracing) of the run's spans here")
    ap.add_argument("--metrics", default=None,
                    help="append metrics JSONL here (summarize with "
                         "python -m repro.obs.report)")
    ap.add_argument("--profile-dir", default=None,
                    help="arm a jax.profiler window (device-level XLA "
                         "timing) writing to this directory")
    ap.add_argument("--profile-block", type=int, default=None,
                    help="which evolution block the profiler window wraps "
                         "(default 0)")
    args = ap.parse_args()
    run_dataset(args.dataset, generations=args.generations, pop=args.pop,
                depth=args.depth, backend=args.backend,
                topology=parse_mesh(args.mesh), archive=args.archive,
                seed=args.seed, ckpt_dir=args.ckpt_dir, seeds=args.seed_exprs,
                archive_every=args.archive_every, islands=args.islands,
                migrate_every=args.migrate_every, migrate_k=args.migrate_k,
                island_topology=args.island_topology,
                chunk_rows=args.chunk_rows, trace=args.trace,
                metrics=args.metrics, profile_dir=args.profile_dir,
                profile_block=args.profile_block)


if __name__ == "__main__":
    main()
