"""GP evolution driver — the paper's workload, end to end.

Mirrors Karoo GP's server interface (§2.2 "scriptable runs via
command-line arguments") and its per-generation archiving (fx_archive_):

    PYTHONPATH=src python -m repro.launch.evolve --dataset kepler \
        --generations 30 --pop 100 --impl pallas --archive /tmp/karoo
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def jnp_asarray(a):
    return jnp.asarray(a)

from repro.core import GPConfig, TreeSpec, FitnessSpec, init_state, evolve_step
from repro.core import primitives as prim
from repro.core.trees import to_string
from repro.data.datasets import BY_NAME
from repro.data.loader import feature_major


def run_dataset(name: str, *, generations: int = 30, pop: int = 100,
                depth: int = 5, impl: str = "jnp", fn_set: str = "auto",
                archive: str | None = None, seed: int = 0, log=print,
                ckpt_dir: str | None = None, ckpt_every: int = 10,
                seeds=None):
    X_rows, y, meta = BY_NAME[name]()
    F = X_rows.shape[1]
    if fn_set == "auto":
        fset = prim.KITCHEN_SINK if name == "kepler" else prim.CLASSIFY_SET
    else:
        fset = prim.FunctionSet.make(fn_set.split(","))
    spec = TreeSpec(max_depth=depth, n_features=F, n_consts=8, fn_set=fset)
    cfg = GPConfig(name=f"karoo-{name}", pop_size=pop, tree_spec=spec,
                   fitness=FitnessSpec(meta["kernel"],
                                       n_classes=meta.get("n_classes", 3)),
                   generations=generations, eval_impl=impl)
    X = feature_major(X_rows)
    state = init_state(cfg, jax.random.PRNGKey(seed), seeds=seeds)
    manager = None
    start_gen = 0
    if ckpt_dir:
        from repro.ckpt.checkpoint import CheckpointManager

        manager = CheckpointManager(ckpt_dir, every=ckpt_every)
        restored, g0 = manager.restore_latest(like=jax.device_get(state))
        if restored is not None:
            state = jax.tree.map(jnp_asarray, restored)
            start_gen = int(g0)
            log(f"resumed from generation {start_gen}")
    consts = np.asarray(spec.const_table())
    t0 = time.time()
    history = []
    for g in range(start_gen, generations):
        state = evolve_step(cfg, state, X, y)
        if manager:
            manager.maybe_save(state, g + 1)
        best = float(state.best_fitness)
        history.append(best)
        if archive:
            os.makedirs(archive, exist_ok=True)
            rec = {"generation": g, "best_fitness": best,
                   "best_tree": to_string(np.asarray(state.best_op),
                                          np.asarray(state.best_arg),
                                          const_table=consts),
                   "population_fitness": np.asarray(state.fitness).tolist()}
            with open(os.path.join(archive, f"gen_{g:04d}.json"), "w") as f:
                json.dump(rec, f)
        if g % 5 == 0 or g == generations - 1:
            log(f"gen {g:3d} best_fitness {best:.5f}")
    if manager:
        manager.maybe_save(state, generations, force=True)
        manager.wait()
    wall = time.time() - t0
    tree = to_string(np.asarray(state.best_op), np.asarray(state.best_arg),
                     const_table=consts)
    log(f"[{name}] {generations} generations in {wall:.2f}s — best: {tree}")
    return state, wall, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="kepler", choices=sorted(BY_NAME))
    ap.add_argument("--generations", type=int, default=30)
    ap.add_argument("--pop", type=int, default=100)
    ap.add_argument("--depth", type=int, default=5)
    ap.add_argument("--impl", default="jnp", choices=("jnp", "pallas"))
    ap.add_argument("--archive", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed-exprs", nargs="*", default=None,
                    help="seed population expressions, e.g. '(x0 * x1)'")
    args = ap.parse_args()
    run_dataset(args.dataset, generations=args.generations, pop=args.pop,
                depth=args.depth, impl=args.impl, archive=args.archive,
                seed=args.seed, ckpt_dir=args.ckpt_dir, seeds=args.seed_exprs)


if __name__ == "__main__":
    main()
