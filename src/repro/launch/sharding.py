"""Parameter / state / batch sharding rules (FSDP × TP).

Every model in the zoo follows one set of path-based rules:

  * tensor-parallel (`model` axis): attention heads, FFN hidden, experts
    (or per-expert ff when E doesn't divide the axis), vocab.
  * FSDP (`data` (+`pod`) axes): the other large dim of every matrix —
    params, master copies and optimizer moments all shard over the full
    mesh, which is what lets 123B/398B configs fit 16 GB/chip (the
    dry-run's memory_analysis is the check). XLA inserts the per-layer
    all-gather inside the scan-over-groups loop (ZeRO-3 style) and its
    latency-hiding scheduler overlaps it with the previous group's
    compute.

Activation shardings come from ShardingPolicy constraints inside the
model code; everything else is propagated by SPMD.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.model import ArchConfig

_TP = "model"


def _fsdp(policy) -> tuple:
    return tuple(policy.batch)  # ("data",) or ("pod", "data")


def _axis_sizes(mesh) -> dict:
    return {name: int(size) for name, size in zip(mesh.axis_names, mesh.devices.shape)}


def _fit(shape, lead, candidates, sizes) -> P:
    """First candidate whose named axes evenly divide the dims they shard.
    NamedSharding rejects uneven tiling, so e.g. gemma's kv=1 falls back
    from head-sharding to head-dim-sharding to replication."""
    for cand in candidates:
        ok = True
        for dim, ax in zip(shape[len(lead):], cand):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= sizes[a]
            if dim % n:
                ok = False
                break
        if ok:
            return P(*lead, *cand)
    return P(*lead, *([None] * (len(shape) - len(lead))))


def spec_for_param(cfg: ArchConfig, path: tuple, shape: tuple, sizes: dict) -> P:
    """PartitionSpec for one parameter leaf, by path name. Candidates are
    ordered best-first; divisibility picks the first legal one."""
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    leaf = names[-1]
    fs = _fsdp(cfg.policy)
    stacked = any(n in ("stack", "enc_stack") for n in names)
    lead = (None,) if stacked else ()

    def fit(*cands):
        return _fit(shape, lead, cands, sizes)

    if leaf == "embed":
        return _fit(shape, (), [(_TP, fs), (None, fs), (None, None)], sizes)
    if leaf == "unembed":
        return _fit(shape, (), [(fs, _TP), (fs, None), (None, None)], sizes)
    if leaf in ("wq", "wk", "wv"):
        # never shard d_head: rope splits it in half and SPMD then falls back
        # to full rematerialization (replicate+repartition) on every layer
        return fit((fs, _TP, None), (fs, None, None), (None,) * 3)
    if leaf == "wo":
        return fit((_TP, None, fs), (None, None, fs), (None,) * 3)
    if leaf in ("bq", "bk", "bv"):
        return fit((_TP, None), (None, None))
    if leaf in ("w_up", "w_gate", "w_down"):
        if len(shape) - len(lead) == 3:  # MoE expert stacks [E, ., .]
            if leaf == "w_down":  # [E, ff, d]
                return fit((_TP, None, fs), (None, _TP, fs), (None, None, fs))
            return fit((_TP, fs, None), (None, fs, _TP), (None, fs, None))
        if leaf == "w_down":  # [ff, d]
            return fit((_TP, fs), (None, fs), (None, None))
        return fit((fs, _TP), (fs, None), (None, None))
    if leaf == "router":
        return fit((None, None))
    if leaf == "in_proj":
        return fit((fs, _TP), (fs, None), (None, None))
    if leaf == "out_proj":
        return fit((_TP, fs), (None, fs), (None, None))
    if leaf == "conv_w":
        return fit((None, _TP), (None, None))
    if leaf == "conv_b":
        return fit((_TP,), (None,))
    # norms, scalars (A_log, D, dt_bias), biases → replicated
    return P(*lead, *([None] * (len(shape) - len(lead))))


def param_specs(cfg: ArchConfig, param_shapes, mesh) -> Any:
    sizes = _axis_sizes(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_param(cfg, path, leaf.shape, sizes), param_shapes)


def _opt_specs(cfg: ArchConfig, pspecs, opt_shapes) -> Any:
    """Mirror param specs onto optimizer slots (AdamW m/v: same shape;
    Adafactor r/c: param spec minus the averaged dim)."""

    def mirror(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        # strip the optimizer container prefix ("m"/"v"/"stats") and the
        # factored suffix ("r"/"c"/"v") to locate the param path
        core = [n for n in names if n not in ("m", "v", "stats", "r", "c")]
        suffix = names[-1] if names[-1] in ("r", "c", "v") else None
        node = pspecs
        try:
            for n in core:
                node = node[n]
        except (KeyError, TypeError):
            return P(*([None] * len(leaf.shape)))
        if not isinstance(node, P):
            return P(*([None] * len(leaf.shape)))
        if len(node) == len(leaf.shape):
            return node
        if suffix == "r":  # param spec minus last dim
            return P(*node[:-1])
        if suffix == "c":  # param spec minus second-to-last dim
            return P(*node[:-2], node[-1])
        return P(*([None] * len(leaf.shape)))

    return jax.tree_util.tree_map_with_path(mirror, opt_shapes)


def train_state_specs(cfg: ArchConfig, state_shapes, mesh) -> Any:
    pspecs = param_specs(cfg, state_shapes["params"], mesh)
    return {"params": pspecs,
            "opt": _opt_specs(cfg, pspecs, state_shapes["opt"]),
            "step": P()}


def batch_specs(cfg: ArchConfig, batch_shapes) -> Any:
    b = tuple(cfg.policy.batch)
    return jax.tree.map(lambda leaf: P(b, *([None] * (len(leaf.shape) - 1))),
                        batch_shapes)


def cache_specs(cfg: ArchConfig, cache_shapes, mesh, *, seq_shard: bool) -> Any:
    """KV/SSM cache sharding. Normal decode: batch over data, kv-heads/ssm
    heads over model. long-context (batch=1): sequence over data
    (context parallelism) — the flash-merge optimization in
    launch/serving.py consumes the same layout."""
    b = tuple(cfg.policy.batch)
    sizes = _axis_sizes(mesh)
    bb = None if seq_shard else b
    sq = b if seq_shard else None

    def one(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        leafname = names[-1]
        lead = (None,)
        if leafname in ("k", "v"):  # [G, B, S, KV, hd]
            return _fit(leaf.shape, lead,
                        [(bb, sq, _TP, None), (bb, sq, None, _TP), (bb, sq, None, None)],
                        sizes)
        if leafname in ("ck", "cv"):  # [G, B, M, KV, hd]
            return _fit(leaf.shape, lead,
                        [(bb, None, _TP, None), (bb, None, None, _TP),
                         (bb, None, None, None)], sizes)
        if leafname == "ssm":  # [G, B, H, N, P]
            return _fit(leaf.shape, lead,
                        [(bb, _TP, None, None), (None, _TP, None, None),
                         (None, None, None, None)], sizes)
        if leafname == "conv":  # [G, B, K-1, conv_dim]
            return _fit(leaf.shape, lead,
                        [(bb, None, _TP), (None, None, _TP), (None, None, None)],
                        sizes)
        return P(*([None] * len(leaf.shape)))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def named(mesh, spec_tree, shape_tree):
    """Attach NamedShardings to a ShapeDtypeStruct tree (for .lower())."""
    return jax.tree.map(
        lambda sds, spec: jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                               sharding=NamedSharding(mesh, spec)),
        shape_tree, spec_tree)
