"""LM training driver: config → mesh → sharded train loop with
checkpoint/restart. Runs reduced configs end-to-end on CPU (examples/)
and full configs on a real pod with the same code path.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro import compat
from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import get_config, get_reduced
from repro.data.loader import lm_batches
from repro.launch import sharding as SH
from repro.launch.mesh import batch_axes, make_host_mesh
from repro.models import model as Md
from repro.models.transformer import ShardingPolicy
from repro.optim.adamw import for_config
from repro.runtime.fault import StepMonitor


def build(cfg, mesh, seed: int = 0):
    dp = 1
    for a in batch_axes(mesh):
        dp *= mesh.shape[a]
    policy = ShardingPolicy(batch=batch_axes(mesh), model="model",
                            tp_size=mesh.shape["model"], dp_size=dp)
    cfg = cfg.with_policy(policy)
    opt = for_config(cfg)

    def init_state(key):
        params = Md.init_params(cfg, key)
        return {"params": params, "opt": opt.init(params),
                "step": jnp.zeros((), jnp.int32)}

    state_shapes = jax.eval_shape(init_state, jax.random.PRNGKey(seed))
    specs = SH.train_state_specs(cfg, state_shapes, mesh)
    with compat.set_mesh(mesh):
        state = jax.jit(
            init_state,
            out_shardings=jax.tree.map(lambda s: NamedSharding(mesh, s), specs),
        )(jax.random.PRNGKey(seed))
    step = jax.jit(Md.make_train_step(cfg, opt, param_specs=specs["params"]),
                   donate_argnums=(0,))
    return cfg, state, step, specs


def train(cfg, *, steps: int, batch: int, seq: int, ckpt_dir: str | None = None,
          ckpt_every: int = 50, mesh=None, log=print, seed: int = 0):
    mesh = mesh or make_host_mesh(data=max(1, len(jax.devices())), model=1)
    cfg, state, step, specs = build(cfg, mesh, seed)
    manager = CheckpointManager(ckpt_dir, every=ckpt_every) if ckpt_dir else None
    if manager is not None:
        restored, s0 = manager.restore_latest(like=jax.device_get(state))
        if restored is not None:
            from repro.ckpt.elastic import reshard_tree
            state = reshard_tree(restored, specs, mesh)
            log(f"resumed from step {s0}")
    monitor = StepMonitor()
    stream = lm_batches(cfg.vocab, batch, seq)
    history = []
    with compat.set_mesh(mesh):
        start = int(state["step"])
        for i, b in zip(range(start, steps), stream):
            with monitor:
                state, metrics = step(state, b)
            loss = float(metrics["loss"])
            history.append(loss)
            if manager:
                manager.maybe_save(state, i + 1)
            if i % 10 == 0 or i == steps - 1:
                log(f"step {i} loss {loss:.4f} ema_s {monitor.ema and round(monitor.ema, 3)}")
    if manager:
        manager.maybe_save(state, steps, force=True)
        manager.wait()
    return state, history, monitor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()
    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced and cfg.accum_steps > 1 and args.batch % cfg.accum_steps:
        import dataclasses
        cfg = dataclasses.replace(cfg, accum_steps=1)
    t0 = time.time()
    _, history, monitor = train(cfg, steps=args.steps, batch=args.batch,
                                seq=args.seq, ckpt_dir=args.ckpt_dir,
                                ckpt_every=args.ckpt_every)
    print(f"final loss {history[-1]:.4f} (from {history[0]:.4f}) "
          f"in {time.time()-t0:.1f}s; stragglers: {len(monitor.stragglers)}")


if __name__ == "__main__":
    main()
