"""GP service driver — a multi-tenant job stream through repro.service.

Feeds a batch of heterogeneous GP jobs (from a JSON job file, or a
synthetic stream) into one `GPService` and drains the queue, printing
each job's published result:

    # 12 synthetic ragged jobs packed into 4 slots
    PYTHONPATH=src python -m repro.launch.serve_gp --jobs 12 --slots 4

    # jobs from a file, with checkpoint/restart armed
    PYTHONPATH=src python -m repro.launch.serve_gp \
        --job-file jobs.json --slots 8 --ckpt-dir /tmp/gp-svc

A job file is a JSON list; each entry names a dataset from
repro.data.datasets plus any JobSpec overrides:

    [{"dataset": "kepler", "generations": 30, "seed": 0},
     {"dataset": "iris", "kernel": "c", "n_classes": 3, "rows": 60}]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.data.datasets import BY_NAME
from repro.service import GPService, JobSpec


def synthetic_stream(n_jobs: int, *, seed: int = 0, max_rows: int = 96,
                     n_features: int = 3) -> list[JobSpec]:
    """A ragged, heterogeneous job stream: varied row counts, kernels,
    operator mixes, budgets and stop bars — the tens-to-hundreds-of-rows
    regime the service exists for."""
    from repro.core.evolve import OperatorMix

    r = np.random.RandomState(seed)
    kernels = ("r", "mse", "pearson")
    mixes = (OperatorMix(), OperatorMix(0.05, 0.05, 0.05, 0.85),
             OperatorMix(0.10, 0.30, 0.30, 0.30))
    jobs = []
    for i in range(n_jobs):
        rows = int(r.randint(max_rows // 4, max_rows + 1))
        X = r.randn(rows, n_features).astype(np.float32)
        y = (X[:, 0] * X[:, 1] + np.sin(X[:, 0])).astype(np.float32)
        jobs.append(JobSpec(
            X, y, kernel=kernels[i % len(kernels)], mix=mixes[i % len(mixes)],
            generations=int(r.randint(10, 40)),
            stop_fitness=1e-5 if i % 4 == 0 else None,
            seed=i, name=f"synthetic-{i}"))
    return jobs


def load_job_file(path: str, *, data_cap: int) -> list[JobSpec]:
    """JSON job list → JobSpecs; each entry names a dataset (optionally
    truncated via "rows") plus JobSpec overrides."""
    with open(path) as f:
        entries = json.load(f)
    jobs = []
    for i, e in enumerate(entries):
        e = dict(e)
        name = e.pop("dataset")
        X_rows, y, meta = BY_NAME[name]()
        rows = int(e.pop("rows", min(len(y), data_cap)))
        X_rows, y = X_rows[:rows], y[:rows]
        e.setdefault("kernel", meta["kernel"])
        if "n_classes" in meta:
            e.setdefault("n_classes", meta["n_classes"])
        e.setdefault("name", f"{name}-{i}")
        jobs.append(JobSpec(X_rows, y, **e))
    return jobs


def serve(jobs: list[JobSpec], *, slots: int = 4, pop: int = 64,
          depth: int = 5, data_cap: int = 128, block_size: int = 8,
          strategy: str = "fifo", ckpt_dir: str | None = None,
          ckpt_every: int = 1, log=print, trace: str | None = None,
          metrics: str | None = None):
    """Submit every job, drain the queue, report. Returns (service,
    handles in submit order). `trace`/`metrics` are output paths arming
    the repro.obs Tracer (Chrome trace JSON with per-job lifetime lanes)
    and Metrics JSONL sink — see docs/observability.md."""
    from repro.obs import Metrics, Tracer

    tracer = Tracer(trace) if trace else None
    mreg = Metrics(metrics) if metrics else None
    n_features = max(j.n_features for j in jobs)
    data_cap = max(data_cap, max(j.n_rows for j in jobs))
    svc = GPService(slots=slots, pop_size=pop, max_depth=depth,
                    n_features=n_features, data_cap=data_cap,
                    block_size=block_size, strategy=strategy,
                    checkpoint_dir=ckpt_dir, checkpoint_every=ckpt_every,
                    tracer=tracer, metrics=mreg)
    handles = [svc.submit(j) for j in jobs]
    t0 = time.time()
    svc.run()
    wall = time.time() - t0
    for h in handles:
        log(f"  [{h.status:9s}] {h.spec.name:16s} kernel={h.spec.kernel:8s} "
            f"gens={h.gens_done:3d}/{h.spec.generations:3d} "
            f"best={h.best_fitness:12.5f}  {h.best_expression}")
    s = svc.stats
    log(f"{len(jobs)} jobs / {slots} slots: {s['blocks']} blocks in "
        f"{wall:.2f}s — {s['admissions']} admissions, {s['evictions']} "
        f"evictions, {s['restarts']} restarts, {s['compiles']} compiled "
        f"program(s)")
    if s["cache_queries"]:
        log(f"  elite cache: {s['cache_hits']}/{s['cache_queries']} hits "
            f"({s['cache_hit_rate']:.2f})")
    if tracer is not None:
        log(f"  trace written to {tracer.save()}")
    if mreg is not None:
        mreg.close()
        log(f"  metrics written to {metrics} "
            f"(summarize: python -m repro.obs.report {metrics})")
    return svc, handles


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--job-file", default=None,
                    help="JSON job list (see module docstring); default is "
                         "a synthetic stream")
    ap.add_argument("--jobs", type=int, default=8,
                    help="synthetic-stream job count (ignored with --job-file)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--pop", type=int, default=64)
    ap.add_argument("--depth", type=int, default=5)
    ap.add_argument("--data-cap", type=int, default=128,
                    help="per-slot row capacity (auto-raised to the largest job)")
    ap.add_argument("--block-size", type=int, default=8,
                    help="generations per dispatch = admission/eviction quantum")
    ap.add_argument("--strategy", default="fifo", choices=["fifo", "lpt"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=1,
                    help="blocks between committed service checkpoints")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None,
                    help="write a Chrome trace JSON (admit/dispatch spans + "
                         "per-job lifetime lanes; open in Perfetto) here")
    ap.add_argument("--metrics", default=None,
                    help="append metrics JSONL here (summarize with "
                         "python -m repro.obs.report)")
    args = ap.parse_args()
    jobs = (load_job_file(args.job_file, data_cap=args.data_cap)
            if args.job_file
            else synthetic_stream(args.jobs, seed=args.seed))
    serve(jobs, slots=args.slots, pop=args.pop, depth=args.depth,
          data_cap=args.data_cap, block_size=args.block_size,
          strategy=args.strategy, ckpt_dir=args.ckpt_dir,
          ckpt_every=args.ckpt_every, trace=args.trace,
          metrics=args.metrics)


if __name__ == "__main__":
    main()
