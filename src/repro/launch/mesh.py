"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run must set XLA_FLAGS
before the first jax call, and smoke tests must keep seeing 1 device.

Axes:
  pod    inter-pod data parallelism / GP island axis (2 pods = 512 chips)
  data   intra-pod data parallelism + FSDP param sharding + GP data rows
  model  tensor/expert parallelism + GP population sharding
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Small mesh over however many (fake) devices the host exposes —
    used by integration tests."""
    if pod > 1:
        return compat.make_mesh((pod, data, model), ("pod", "data", "model"))
    return compat.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
