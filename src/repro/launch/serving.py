"""Context-parallel decode: flash-merge attention over a sequence-sharded
KV cache.

The long-context decode cells (`long_500k`, batch=1) shard the KV cache on
the sequence dim over the `data` axis (launch/sharding.py `cache_specs`).
Under auto-SPMD the softmax over a sharded sequence makes XLA gather
logits; this module is the explicit alternative: every shard computes a
partial attention over its local cache slice and the shards merge with
the flash identity

    m  = pmax(m_i)
    l  = psum(l_i · exp(m_i − m))
    o  = psum(o_i · exp(m_i − m)) / l

so the wire traffic per layer is O(B·H·hd) instead of O(B·H·S/shards).
The cache write lands only on the owning shard. Numerics are pinned
against layers.attn_decode in tests/test_serving.py.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models.layers import AttnDims, _qkv


def _local_attend(q, k, v, valid, scale):
    """q:[B,1,H,hd]; k,v:[B,S_loc,KV,hd]; valid:[S_loc] bool.
    Returns (o [B,1,H,hd] f32 unnormalized, m [B,1,H], l [B,1,H])."""
    groups = q.shape[2] // k.shape[2]
    kq = jnp.repeat(k, groups, axis=2)
    vq = jnp.repeat(v, groups, axis=2)
    s = jnp.einsum("bthk,bshk->bhts", q, kq.astype(q.dtype),
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    m = s.max(-1)  # [B,H,1]
    m_safe = jnp.where(jnp.isfinite(m), m, -1e30)
    p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_safe[..., None]), 0.0)
    l = p.sum(-1)
    o = jnp.einsum("bhts,bshk->bthk", p.astype(vq.dtype), vq,
                   preferred_element_type=jnp.float32)
    return o, m_safe.transpose(0, 2, 1), l.transpose(0, 2, 1)


def make_cp_decode_attention(dims: AttnDims, seq_axis: str = "data"):
    """Build the shard_map body for one decode-attention layer with a
    seq-sharded cache. Returns fn(p, x, cache_k, cache_v, cur_len) →
    (attn_out [B,1,d], new_k, new_v); call inside shard_map/jit with
    cache specs P(batch?, seq_axis, None, None)."""
    scale = 1.0 / math.sqrt(dims.d_head)

    def attend(p, x, cache_k, cache_v, cur_len):
        nshard = compat.axis_size(seq_axis)
        rank = jax.lax.axis_index(seq_axis)
        S_loc = cache_k.shape[1]
        offset = rank * S_loc

        pos = jnp.full((x.shape[0], 1), cur_len, jnp.int32)
        q, k, v = _qkv(p, x, dims, pos)

        # cache write: only the owning shard applies the update
        local = jnp.clip(cur_len - offset, 0, S_loc - 1)
        owns = (cur_len >= offset) & (cur_len < offset + S_loc)
        upd_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k.astype(cache_k.dtype), local, axis=1)
        upd_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v.astype(cache_v.dtype), local, axis=1)
        new_k = jnp.where(owns, upd_k, cache_k)
        new_v = jnp.where(owns, upd_v, cache_v)

        valid = (jnp.arange(S_loc) + offset) <= cur_len
        o, m, l = _local_attend(q, new_k, new_v, valid, scale)

        # flash merge across shards: O(B·H·hd) on the wire
        m_g = jax.lax.pmax(m, seq_axis)
        c = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * c, seq_axis)
        o_g = jax.lax.psum(o * c[..., None], seq_axis)
        out = (o_g / jnp.maximum(l_g, 1e-30)[..., None]).astype(x.dtype)
        return jnp.einsum("bthk,hkd->btd", out, p["wo"]), new_k, new_v

    return attend


def cp_decode_attention(p, x, cache_k, cache_v, cur_len, dims: AttnDims,
                        mesh, *, seq_axis: str = "data", batch_axes: tuple = ()):
    """Convenience jit'able wrapper: shard_map over `mesh` with the cache
    sequence dim on `seq_axis` (the long_500k layout)."""
    attend = make_cp_decode_attention(dims, seq_axis)
    b = tuple(batch_axes) if batch_axes else None
    cache_spec = P(b, seq_axis, None, None)
    xspec = P(b, None, None)
    pspec = jax.tree.map(lambda _: P(), p)
    return compat.shard_map(
        attend,
        mesh=mesh,
        in_specs=(pspec, xspec, cache_spec, cache_spec, P()),
        out_specs=(xspec, cache_spec, cache_spec),
    )(p, x, cache_k, cache_v, cur_len)
