"""JAX version-compatibility shims.

The codebase targets the current jax API (`jax.shard_map`, `jax.set_mesh`,
`jax.sharding.AxisType`, dict-valued `Compiled.cost_analysis()`), but the
deployment containers pin a range of releases down to 0.4.x, where those
live under different names (`jax.experimental.shard_map.shard_map` with
`check_rep`, the `Mesh` context manager, no axis types, list-valued
cost analysis). Every call site goes through this module so the rest of
the tree is version-agnostic.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit/auto axis types
    from jax.sharding import AxisType as _AxisType
except ImportError:  # jax 0.4.x: all axes behave like Auto
    _AxisType = None


def make_mesh(axis_shapes, axis_names):
    """`jax.make_mesh` with Auto axis types where the installed jax has them."""
    if _AxisType is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(_AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


if hasattr(jax, "shard_map"):

    def shard_map(f, *, in_specs, out_specs, mesh=None):
        if mesh is None:
            return jax.shard_map(f, in_specs=in_specs, out_specs=out_specs,
                                 check_vma=False)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)

else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def _active_mesh():
        from jax._src.mesh import thread_resources

        mesh = thread_resources.env.physical_mesh
        if mesh.empty:
            raise ValueError("shard_map without mesh= needs an active mesh "
                             "context (`with compat.set_mesh(mesh): ...`)")
        return mesh

    def shard_map(f, *, in_specs, out_specs, mesh=None):
        return _shard_map(f, mesh=mesh if mesh is not None else _active_mesh(),
                          in_specs=in_specs, out_specs=out_specs,
                          check_rep=False)


def set_mesh(mesh):
    """Context manager entering `mesh`. On current jax this is
    `jax.set_mesh`; on 0.4.x the Mesh object itself is the context."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def axis_size(name) -> int:
    """Static size of a named mesh axis from inside shard_map
    (`jax.lax.axis_size` on current jax; the axis frame on 0.4.x)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    from jax._src.core import axis_frame

    frame = axis_frame(name)  # returns the bare size on some 0.4.x releases
    return frame if isinstance(frame, int) else frame.size


def cost_analysis(compiled) -> dict:
    """`Compiled.cost_analysis()` as a flat dict on every jax version
    (0.4.x returns a per-device list of dicts)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost
