"""repro.obs — observability for the GP stack.

Three pieces (see docs/observability.md):

- `counters`: the on-device `[K, C]` telemetry counter stream contract
  that every evolution-block scan emits alongside best-fitness —
  telemetry rides the existing one-sync-per-block dispatch and is
  computed unconditionally, so enabling it never recompiles and never
  changes a trajectory.
- `trace.Tracer`: Chrome-trace-event JSON spans (Perfetto-viewable)
  for ingest, block dispatch, chunk folds, checkpoints, and service
  admission/eviction/job lifetimes; `NULL_TRACER` is the no-op default.
- `metrics.Metrics`: counters/gauges/EMA summaries with a JSONL sink;
  `metrics.BlockMonitor` routes ALL block timing through one
  `runtime.fault.StepMonitor` wrapper. `python -m repro.obs.report`
  renders a run's JSONL (and optionally its trace) as a table.
"""
from repro.obs import counters  # noqa: F401
from repro.obs.metrics import BlockMonitor, Metrics  # noqa: F401
from repro.obs.trace import (  # noqa: F401
    NULL_TRACER,
    NullTracer,
    Tracer,
    validate_trace,
)
