"""The on-device telemetry counter stream — column contract.

Every evolution-block scan body (`engine.evolve_block`,
`engine.sharded_evolve_block`, `engine.build_tenant_block`) emits one
`int32[C]` counter row per scanned generation alongside the
best-fitness stream, so a block dispatch returns an `int32[K, C]`
telemetry block that rides back to the host with the SAME single
block-boundary sync as the state and history — telemetry never adds a
host round-trip, and because the counters are computed unconditionally
the compiled program is identical whether a Tracer/Metrics sink is
attached or not (tracing on/off is purely a host-side decision, pinned
bitwise by tests/test_obs.py).

Columns (index into the trailing axis; see docs/observability.md):

    CACHE_HITS     elite-cache hit gates that matched this generation
                   (0/1 single-population and island layouts — one
                   all-islands gate; per-slot for the tenant batch)
    CACHE_QUERIES  hit gates evaluated (0 when the cache is disabled,
                   so hits/queries is the run's cache hit rate)
    FROZEN         scan steps (slots, for the tenant batch) that ran
                   frozen this generation — early-stopped, past the
                   dynamic block `limit`, or an empty/finished tenant
                   slot; their compute was executed and discarded
    MIGRATIONS     island-migration events that came due
    TREE_EVALS     productive tree evaluations: population rows scored
                   against the full dataset, excluding cache-served
                   rows and frozen steps (multiply by the real row
                   count for the paper's trees·rows metric)
    SUBTREE_EVALS_SAVED
                   subtree evaluations the exact-tier dedup avoided
                   this generation: total active subtree spans across
                   the PRE-step population minus the distinct count
                   (0 when dedup is off, the genome is not postfix, or
                   the plan overflowed its cap and fell back)
    UNIQUE_SUBTREES
                   distinct subexpressions in the PRE-step population
                   (0 when dedup is off or the genome is not postfix;
                   still the true distinct count when the plan
                   overflowed, which is how a too-small cap shows up
                   in telemetry) — saved / (saved + unique) is the
                   generation's duplicate rate

Mesh notes: the sharded step bodies carry the elite cache through
untouched (it is host/single-device machinery), so CACHE_* columns are
0 on a mesh; the dedup columns are likewise 0 on a mesh and in the
tenant batch (re-running the signature sort per shard/slot purely for
telemetry would double the plan cost); every other column is computed
from replicated quantities and is identical on all shards.
"""
from __future__ import annotations

COUNTERS = ("cache_hits", "cache_queries", "frozen", "migrations",
            "tree_evals", "subtree_evals_saved", "unique_subtrees")
(CACHE_HITS, CACHE_QUERIES, FROZEN, MIGRATIONS, TREE_EVALS,
 SUBTREE_EVALS_SAVED, UNIQUE_SUBTREES) = range(7)
N_COUNTERS = len(COUNTERS)


def totals(rows) -> dict:
    """Sum an `int32[K, C]` telemetry block into a {column: int} dict —
    the host-side absorption step (`GPSession`/`GPService` fold these
    into their `stats`)."""
    import numpy as np

    rows = np.asarray(rows)
    if rows.ndim == 1:
        rows = rows[None]
    tot = rows.sum(axis=0)
    return {name: int(tot[i]) for i, name in enumerate(COUNTERS)}


def hit_rate(stats: dict) -> float:
    """cache_hits / cache_queries from a stats dict (0.0 before any
    query — a disabled cache never divides by zero)."""
    q = stats.get("cache_queries", 0)
    return stats.get("cache_hits", 0) / q if q else 0.0
