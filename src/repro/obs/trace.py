"""Chrome-trace-event tracing for the GP stack (host side).

A `Tracer` collects trace events in memory and writes the Chrome Trace
Event JSON object format (`{"traceEvents": [...]}`) — open the file at
`chrome://tracing` or https://ui.perfetto.dev to see ingest, block
dispatches, chunk folds, checkpoint saves and service admission/
eviction as nested spans on a per-thread timeline, and per-job
lifetimes as async tracks. `NULL_TRACER` is the always-on no-op every
instrumented call site defaults to, so tracing-off costs one attribute
lookup and no allocation — the device programs never see the tracer at
all (the counter stream is unconditional; see obs/counters.py), which
is what keeps traced and untraced trajectories bitwise identical.

Span discipline: `span()` emits a "B" event and ALWAYS emits the
matching "E" on exit (try/finally), so every written trace nests
properly — tests/test_obs.py walks the B/E stack per thread and
rejects orphans. Async job lifetimes use "b"/"e" events keyed by id.

An optional `jax.profiler` window can be armed around one chosen
evolution block (`profile_dir=`, `profile_block=`): the session asks
`maybe_profile(block_index)` at each dispatch and exactly that block
runs under `jax.profiler.start_trace` — device-level XLA timing for
one block, without paying profiler overhead for the whole run.
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager, nullcontext


class NullTracer:
    """No-op tracer: every method returns immediately; `span`/`maybe_
    profile` return a shared nullcontext. Instrumented code calls the
    tracer unconditionally and never branches on enablement."""

    enabled = False

    def span(self, name, cat="repro", args=None):
        return nullcontext()

    def instant(self, name, cat="repro", args=None):
        pass

    def counter(self, name, values, cat="repro"):
        pass

    def begin_async(self, name, aid, cat="repro", args=None):
        pass

    def end_async(self, name, aid, cat="repro", args=None):
        pass

    def maybe_profile(self, block_index):
        return nullcontext()

    def save(self, path=None):
        return None


NULL_TRACER = NullTracer()


class Tracer:
    """Collects Chrome trace events; thread-safe appends; one process.

    `path` (optional) is where `save()` writes by default; pass
    `profile_dir`/`profile_block` to arm a jax.profiler window around
    the `profile_block`-th dispatched evolution block."""

    enabled = True

    def __init__(self, path: str | None = None, *,
                 profile_dir: str | None = None,
                 profile_block: int | None = None):
        self.path = path
        self.profile_dir = profile_dir
        self.profile_block = (profile_block if profile_block is not None
                              else (0 if profile_dir else None))
        self.events: list[dict] = []
        self._lock = threading.Lock()
        self._async_open: set[tuple] = set()
        self._t0 = time.perf_counter()
        self._pid = os.getpid()
        self._emit({"ph": "M", "name": "process_name", "pid": self._pid,
                    "tid": 0, "args": {"name": "repro-gp"}})

    # --- low level ------------------------------------------------------------

    def _ts(self) -> float:
        """Microseconds since tracer construction (Chrome trace unit)."""
        return (time.perf_counter() - self._t0) * 1e6

    def _emit(self, ev: dict):
        with self._lock:
            self.events.append(ev)

    def _base(self, ph, name, cat, args):
        ev = {"ph": ph, "name": name, "cat": cat, "ts": self._ts(),
              "pid": self._pid, "tid": threading.get_ident()}
        if args:
            ev["args"] = dict(args)
        return ev

    # --- spans / instants / counters ------------------------------------------

    @contextmanager
    def span(self, name, cat="repro", args=None):
        """Duration span: B on entry, E on exit — the E is emitted even
        when the body raises, so traces always nest."""
        self._emit(self._base("B", name, cat, args))
        try:
            yield self
        finally:
            self._emit(self._base("E", name, cat, None))

    def instant(self, name, cat="repro", args=None):
        ev = self._base("i", name, cat, args)
        ev["s"] = "t"  # thread-scoped instant
        self._emit(ev)

    def counter(self, name, values: dict, cat="repro"):
        """Chrome counter track: `values` is {series: number}."""
        self._emit(self._base("C", name, cat,
                              {k: float(v) for k, v in values.items()}))

    def begin_async(self, name, aid, cat="repro", args=None):
        """Open an async lifetime lane. Idempotent per (name, id): a
        rollback/replay path re-opening a live lane is a no-op, so the
        written trace always pairs b/e events."""
        ev = self._base("b", name, cat, args)
        ev["id"] = str(aid)
        with self._lock:
            key = (name, ev["id"])
            if key in self._async_open:
                return
            self._async_open.add(key)
            self.events.append(ev)

    def end_async(self, name, aid, cat="repro", args=None):
        """Close an async lane; a close with no open lane (replayed
        publish after a restart rollback) is a no-op."""
        ev = self._base("e", name, cat, args)
        ev["id"] = str(aid)
        with self._lock:
            key = (name, ev["id"])
            if key not in self._async_open:
                return
            self._async_open.discard(key)
            self.events.append(ev)

    # --- jax.profiler window --------------------------------------------------

    @contextmanager
    def _profile_window(self):
        import jax

        jax.profiler.start_trace(self.profile_dir)
        try:
            yield self
        finally:
            jax.profiler.stop_trace()

    def maybe_profile(self, block_index: int):
        """Context manager: a real jax.profiler window when this is the
        armed block, a no-op otherwise."""
        if self.profile_dir is not None and block_index == self.profile_block:
            return self._profile_window()
        return nullcontext()

    # --- output ---------------------------------------------------------------

    def save(self, path: str | None = None) -> str:
        """Write `{"traceEvents": [...]}` (the Chrome trace JSON object
        form — Perfetto and chrome://tracing both open it). Returns the
        path written."""
        path = path or self.path
        if path is None:
            raise ValueError("Tracer has no path — pass save(path) or "
                             "construct with Tracer(path)")
        with self._lock:
            events = list(self.events)
        payload = {"traceEvents": events, "displayTimeUnit": "ms"}
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(payload, f)
        return path


def validate_trace(payload: dict) -> list[str]:
    """Schema check for a Chrome trace object: returns a list of
    problems (empty = valid). Checks the envelope, per-(pid, tid) B/E
    stack discipline (no orphan E, no unclosed B, E names match their
    B), and that async b/e events pair up per (name, id)."""
    problems = []
    events = payload.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents is not a list"]
    stacks: dict[tuple, list] = {}
    async_open: dict[tuple, int] = {}
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph in ("B", "E"):
            key = (ev.get("pid"), ev.get("tid"))
            stack = stacks.setdefault(key, [])
            if ph == "B":
                stack.append(ev.get("name"))
            else:
                if not stack:
                    problems.append(f"event {i}: orphan E {ev.get('name')!r}")
                elif stack[-1] != ev.get("name"):
                    problems.append(
                        f"event {i}: E {ev.get('name')!r} closes "
                        f"B {stack[-1]!r} (misnested)")
                    stack.pop()
                else:
                    stack.pop()
        elif ph == "b":
            k = (ev.get("name"), ev.get("id"))
            async_open[k] = async_open.get(k, 0) + 1
        elif ph == "e":
            k = (ev.get("name"), ev.get("id"))
            if async_open.get(k, 0) < 1:
                problems.append(f"event {i}: async e without b for {k}")
            else:
                async_open[k] -= 1
    for (pid, tid), stack in stacks.items():
        for name in stack:
            problems.append(f"unclosed B {name!r} on (pid={pid}, tid={tid})")
    for k, n in async_open.items():
        if n:
            problems.append(f"async b without e for {k}")
    return problems
