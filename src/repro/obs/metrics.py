"""Metrics registry for the GP stack: counters, gauges, EMA histograms.

One `Metrics` object per run. It is the single sink the session, the
service and the CLIs publish into; the legacy `GPSession.stats` /
`GPService.stats` dicts stay as views the tests pin, but their values
are produced here. Three instrument kinds:

  inc(name, n)        monotonic counter (host syncs, blocks, cache hits)
  gauge(name, v)      last-value gauge (slot occupancy, generation)
  observe(name, v)    streaming summary: count/sum/min/max + EMA —
                      a cheap fixed-size histogram substitute for
                      wall-time series (block seconds, chunk seconds)

`Metrics(path=...)` additionally appends one JSON object per `emit()`
call to a JSONL file (one line per event — block timings, chunk folds,
service dispatches), and `close()` writes a final `{"kind":
"snapshot"}` line holding every instrument, which is what
`python -m repro.obs.report` renders. With no path, everything stays
in memory and `snapshot()` serves programmatic readers.

`BlockMonitor` wraps `runtime.fault.StepMonitor` so EVERY block path
(jitted dispatch, host scalar fallback, service drain) reports through
the same timing instrument: one `with` block updates the StepMonitor
EMA + straggler list AND publishes `block_s` observations / legacy
stats keys. This is the fix for `block_s_ema`/`stragglers` only
updating on one of the session's paths.
"""
from __future__ import annotations

import json
import os
import threading
import time


class _Summary:
    __slots__ = ("count", "sum", "min", "max", "ema", "alpha")

    def __init__(self, alpha=0.2):
        self.count = 0
        self.sum = 0.0
        self.min = None
        self.max = None
        self.ema = None
        self.alpha = alpha

    def observe(self, v: float):
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        self.ema = v if self.ema is None else (
            self.alpha * v + (1 - self.alpha) * self.ema)

    def as_dict(self) -> dict:
        mean = self.sum / self.count if self.count else 0.0
        return {"count": self.count, "sum": self.sum, "mean": mean,
                "min": self.min, "max": self.max, "ema": self.ema}


class Metrics:
    """Thread-safe metrics registry with an optional JSONL sink."""

    def __init__(self, path: str | None = None):
        self.path = path
        self._lock = threading.Lock()
        self._counters: dict[str, int] = {}
        self._gauges: dict[str, float] = {}
        self._summaries: dict[str, _Summary] = {}
        self._file = None
        self._t0 = time.time()
        if path:
            d = os.path.dirname(path)
            if d:
                os.makedirs(d, exist_ok=True)
            self._file = open(path, "a")

    # --- instruments ----------------------------------------------------------

    def inc(self, name: str, n: int = 1) -> int:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + int(n)
            return self._counters[name]

    def gauge(self, name: str, value: float):
        with self._lock:
            self._gauges[name] = float(value)

    def observe(self, name: str, value: float):
        with self._lock:
            s = self._summaries.get(name)
            if s is None:
                s = self._summaries[name] = _Summary()
            s.observe(value)

    def counter_value(self, name: str) -> int:
        with self._lock:
            return self._counters.get(name, 0)

    def summary(self, name: str) -> dict | None:
        with self._lock:
            s = self._summaries.get(name)
            return s.as_dict() if s else None

    # --- sink -----------------------------------------------------------------

    def emit(self, kind: str, **fields):
        """Append one event line to the JSONL sink (no-op without a
        path). Every line carries `kind` and `t` (seconds since the
        registry was created)."""
        if self._file is None:
            return
        rec = {"kind": kind, "t": round(time.time() - self._t0, 6)}
        rec.update(fields)
        with self._lock:
            self._file.write(json.dumps(rec) + "\n")

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "summaries": {k: s.as_dict()
                              for k, s in self._summaries.items()},
            }

    def close(self):
        """Flush the final snapshot line and close the sink."""
        if self._file is None:
            return
        snap = self.snapshot()
        with self._lock:
            self._file.write(json.dumps({"kind": "snapshot", **snap}) + "\n")
            self._file.close()
            self._file = None


class BlockMonitor:
    """The one timing path for evolution blocks.

    Wraps a `runtime.fault.StepMonitor` (EMA + straggler detection) and
    publishes each step into a `Metrics` registry and, for
    compatibility, a legacy stats dict (`blocks`, `block_s_ema`,
    `stragglers`). Use as a context manager around each block dispatch,
    on every path — jitted, host fallback, and service drain.
    """

    def __init__(self, monitor, metrics: Metrics,
                 stats: dict | None = None, name: str = "block_s"):
        self.monitor = monitor
        self.metrics = metrics
        self.stats = stats
        self.name = name

    def __enter__(self):
        self.monitor.__enter__()
        return self

    def __exit__(self, *exc):
        out = self.monitor.__exit__(*exc)
        if exc[0] is None:
            self.metrics.inc("blocks")
            if self.monitor.ema is not None:
                self.metrics.observe(self.name, self.monitor.last)
                self.metrics.gauge(self.name + "_ema", self.monitor.ema)
            if self.stats is not None:
                self.stats["blocks"] = self.stats.get("blocks", 0) + 1
                self.stats["block_s_ema"] = self.monitor.ema
                self.stats["stragglers"] = self.monitor.stragglers
            self.metrics.emit("block", seconds=self.monitor.last,
                              ema=self.monitor.ema)
        return out
