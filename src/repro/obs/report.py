"""Render a run's metrics JSONL (and optionally its trace) as a table.

    python -m repro.obs.report metrics.jsonl [--trace trace.json]

Reads the event stream a `Metrics(path=...)` sink wrote — the final
`{"kind": "snapshot"}` line carries every counter/gauge/summary; the
per-event lines give block/chunk timing series. With `--trace`, also
validates the Chrome trace file and prints per-span-name totals.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict


def load_jsonl(path: str) -> list[dict]:
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def _table(rows: list[tuple], header: tuple) -> str:
    rows = [tuple(_fmt(c) for c in r) for r in rows]
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(header)]
    def line(cells):
        return "  ".join(c.ljust(w) for c, w in zip(cells, widths)).rstrip()
    out = [line(header), line(tuple("-" * w for w in widths))]
    out.extend(line(r) for r in rows)
    return "\n".join(out)


def summarize_metrics(records: list[dict]) -> str:
    """Human-readable report from a metrics JSONL record list."""
    snap = None
    for rec in records:
        if rec.get("kind") == "snapshot":
            snap = rec  # last snapshot wins
    parts = []
    if snap is None:
        parts.append("(no snapshot line — run did not close its Metrics "
                     "sink; reporting event lines only)")
    else:
        counters = sorted(snap.get("counters", {}).items())
        if counters:
            parts.append("counters\n" + _table(counters, ("name", "value")))
        gauges = sorted(snap.get("gauges", {}).items())
        if gauges:
            parts.append("gauges\n" + _table(gauges, ("name", "value")))
        summaries = snap.get("summaries", {})
        if summaries:
            rows = [(name, s.get("count"), s.get("mean"), s.get("min"),
                     s.get("max"), s.get("ema"))
                    for name, s in sorted(summaries.items())]
            parts.append("summaries\n" + _table(
                rows, ("name", "count", "mean", "min", "max", "ema")))
        c = snap.get("counters", {})
        q = c.get("cache_queries", 0)
        if q:
            parts.append(f"cache hit rate: {c.get('cache_hits', 0) / q:.3f} "
                         f"({c.get('cache_hits', 0)}/{q})")
        saved = c.get("subtree_evals_saved", 0)
        uniq = c.get("unique_subtrees", 0)
        if saved or uniq:
            rate = saved / (saved + uniq) if saved + uniq else 0.0
            parts.append(f"subtree evals saved by dedup: {saved} "
                         f"(unique subtrees: {uniq}, duplicate rate: "
                         f"{rate:.3f})")
    kinds = defaultdict(int)
    for rec in records:
        kinds[rec.get("kind", "?")] += 1
    parts.append("events\n" + _table(sorted(kinds.items()),
                                     ("kind", "count")))
    return "\n\n".join(parts)


def summarize_trace(path: str) -> str:
    """Validate a Chrome trace file and total wall time per span name."""
    from repro.obs.trace import validate_trace

    with open(path) as f:
        payload = json.load(f)
    problems = validate_trace(payload)
    parts = []
    if problems:
        parts.append("trace problems:\n" + "\n".join(
            f"  - {p}" for p in problems))
    else:
        parts.append("trace: valid (spans nest, no orphan events)")
    # Total B→E durations per name, matching the same stack walk the
    # validator does so misnested traces don't crash the report.
    totals = defaultdict(float)
    counts = defaultdict(int)
    stacks: dict[tuple, list] = {}
    for ev in payload.get("traceEvents", []):
        ph = ev.get("ph")
        if ph == "B":
            stacks.setdefault((ev.get("pid"), ev.get("tid")), []).append(
                (ev.get("name"), ev.get("ts", 0.0)))
        elif ph == "E":
            stack = stacks.get((ev.get("pid"), ev.get("tid")), [])
            if stack:
                name, t0 = stack.pop()
                totals[name] += (ev.get("ts", 0.0) - t0) / 1e6
                counts[name] += 1
    if totals:
        rows = [(name, counts[name], totals[name])
                for name in sorted(totals, key=totals.get, reverse=True)]
        parts.append("spans\n" + _table(rows, ("name", "count",
                                               "total_s")))
    return "\n\n".join(parts)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarize a GP run's metrics JSONL / trace JSON.")
    ap.add_argument("metrics", help="metrics JSONL file from --metrics")
    ap.add_argument("--trace", default=None,
                    help="Chrome trace JSON from --trace")
    args = ap.parse_args(argv)
    print(summarize_metrics(load_jsonl(args.metrics)))
    if args.trace:
        print()
        print(summarize_trace(args.trace))
    return 0


if __name__ == "__main__":
    sys.exit(main())
