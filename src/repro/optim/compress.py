"""int8 gradient compression with error feedback.

Drops the data-parallel all-reduce volume 4x (f32→int8 + per-tensor f32
scale). Error feedback keeps the quantization residual locally and adds
it to the next step's gradient, which is the standard convergence fix
(1-bit Adam / EF-SGD lineage). Exposed two ways:

  * `compressed_psum(grads, axis, residual)` — drop-in for `lax.psum` on
    an explicit shard_map data axis.
  * `quantize/dequantize` — used by tests and by the checkpoint codec.

The roofline's collective term measures the win (§Perf); convergence is
property-tested against uncompressed SGD in tests/test_optim.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat


def quantize(x):
    """f32 → (int8, scale). Symmetric per-tensor scaling."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, axis: str, residual=None):
    """Quantize → psum → dequantize with error feedback.

    grads/residual: pytrees of f32 arrays (local gradient shards inside a
    shard_map body). Returns (mean_grads, new_residual).
    """
    n = compat.axis_size(axis)
    if residual is None:
        residual = jax.tree.map(jnp.zeros_like, grads)

    def one(g, r):
        g_fb = g + r
        # shared scale via a scalar pmax so every shard's int8 grid aligns —
        # per-element error of the mean is then ≤ scale/2 exactly.
        amax = jax.lax.pmax(jnp.max(jnp.abs(g_fb)), axis)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(g_fb / scale), -127, 127).astype(jnp.int8)
        # int8 tensors all-reduce in int32 to avoid overflow across shards
        summed = jax.lax.psum(q.astype(jnp.int32), axis)
        mean = summed.astype(jnp.float32) * scale / n
        new_r = g_fb - dequantize(q, scale)
        return mean, new_r

    out = jax.tree.map(one, grads, residual)
    is_pair = lambda x: isinstance(x, tuple)
    mean = jax.tree.map(lambda o: o[0], out, is_leaf=is_pair)
    new_res = jax.tree.map(lambda o: o[1], out, is_leaf=is_pair)
    return mean, new_res
