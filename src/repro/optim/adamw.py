"""AdamW and Adafactor, functional (init/update pairs).

Adafactor (factored second moment, no first moment by default) exists for
the 100B+ cells: AdamW's 8 bytes/param of moments would blow the per-pod
HBM budget for jamba-1.5-398b (DESIGN.md §4); the dry-run memory_analysis
is the arbiter. Both optimizers apply global-norm clipping and a cosine
schedule, and both keep f32 master params (forward casts to bf16).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return lr


def _global_norm(tree):
    # sum(g*g), NOT vdot: vdot ravels, and reshaping a 2-axis-sharded tensor
    # to 1D forces GSPMD to fully rematerialize it (replicated!) — a
    # >100 GB/device bug at 100B+ params.
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(tree)))


def _clip(grads, max_norm: float):
    gn = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, Any], tuple]  # (grads, state, params, step)


def adamw(lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1, clip_norm=1.0,
          schedule: Callable | None = None) -> Optimizer:
    sched = schedule or (lambda s: lr)

    def init(params):
        z = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"m": z(), "v": z()}

    def update(grads, state, params, step):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        grads = _clip(grads, clip_norm)
        t = jnp.asarray(step, jnp.float32) + 1.0
        lr_t = sched(step)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(p, g, m, v):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            return (p - lr_t * (u + weight_decay * p)).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


def adafactor(lr=1e-3, decay=0.8, eps=1e-30, clip_norm=1.0,
              schedule: Callable | None = None) -> Optimizer:
    """Factored second moment: O(rows+cols) state for matrices, O(n) for
    vectors. No first moment → ~0.01–1 byte/param of optimizer state."""
    sched = schedule or (lambda s: lr)

    def init(params):
        def stat(p):
            if p.ndim >= 2:
                return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                        "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"stats": jax.tree.map(stat, params,
                                      is_leaf=lambda x: isinstance(x, jax.Array))}

    def update(grads, state, params, step):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        grads = _clip(grads, clip_norm)
        t = jnp.asarray(step, jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)
        lr_t = sched(step)

        def upd(p, g, s):
            g2 = g * g + eps
            if p.ndim >= 2:
                r = beta * s["r"] + (1 - beta) * g2.mean(-1)
                c = beta * s["c"] + (1 - beta) * g2.mean(-2)
                denom = (r[..., None] * c[..., None, :]) / jnp.maximum(
                    r.mean(-1, keepdims=True)[..., None], eps)
                u = g * jax.lax.rsqrt(jnp.maximum(denom, eps))
                new_s = {"r": r, "c": c}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            # relative step size (Adafactor's update clipping, d=1.0)
            rms_u = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms_u)
            return (p - lr_t * u).astype(p.dtype), new_s

        out = jax.tree.map(upd, params, grads, state["stats"],
                           is_leaf=lambda x: isinstance(x, jax.Array))
        is_pair = lambda x: isinstance(x, tuple)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=is_pair)
        new_s = jax.tree.map(lambda o: o[1], out, is_leaf=is_pair)
        return new_p, {"stats": new_s}

    return Optimizer(init, update)


def for_config(cfg) -> Optimizer:
    if cfg.optimizer == "adafactor":
        return adafactor()
    return adamw()
