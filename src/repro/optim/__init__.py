"""Distributed optimizer substrate: AdamW, Adafactor, schedules, and
optional int8 gradient compression with error feedback."""
from repro.optim.adamw import adafactor, adamw, cosine_schedule  # noqa: F401
from repro.optim.compress import compressed_psum  # noqa: F401
