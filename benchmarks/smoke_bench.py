"""Loop-throughput smoke benchmark — the perf-trajectory seed for CI.

Times the device-resident generation loop (one `lax.scan` evolution
block per dispatch) on the jnp backend over a fixed synthetic dataset at
the paper's 875x scale point (KAT-7 shape, 90,000 rows) with a pop=256
population, and writes `BENCH_loop.json` so every CI run leaves a
comparable generations/sec artifact:

    PYTHONPATH=src python benchmarks/smoke_bench.py --out BENCH_loop.json

`--bench islands` times the island-model layout instead — 1 island of
256 trees vs 4 heterogeneous islands of 64 (same total trees, same
data), so the artifact (`BENCH_islands.json`) tracks what the
island-batched step costs over the classic layout:

    PYTHONPATH=src python benchmarks/smoke_bench.py --bench islands \
        --out BENCH_islands.json

`--bench service` times the multi-tenant scheduler instead — N small
heterogeneous jobs packed into one compiled island batch by `GPService`
vs the same jobs run back-to-back as solo `islands=1` sessions — so the
artifact (`BENCH_service.json`) tracks the packing win plus the
no-recompile invariant (`service_compiles` must stay 1):

    PYTHONPATH=src python benchmarks/smoke_bench.py --bench service \
        --out BENCH_service.json

`--bench stream` times streaming chunked fitness at the paper's 5.5M-
data-point scale — a `datasets.stream_rows` synthetic stream folded
chunk-by-chunk (`BENCH_stream.json`), with a monolithic comparison when
the row count is small enough to materialize:

    PYTHONPATH=src python benchmarks/smoke_bench.py --bench stream \
        --rows 1100000 --chunk-rows 262144 --out BENCH_stream.json

The numbers are NOT cross-machine comparable (CI runners vary); the
artifact records the machine-free quantities too (generations, rows,
pop, host syncs) so a trajectory can be assembled from like runners.
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax

from repro.data.datasets import kat7
from repro.gp import GPSession, OperatorMix

# the paper's 875x axis: KAT-7 shape at 90k rows (§3.5, Fig. 3)
ROWS = 90_000
POP = 256
GENS = 10


def bench_loop(*, pop: int = POP, rows: int = ROWS, gens: int = GENS,
               depth: int = 5, seed: int = 0, trace: str | None = None,
               metrics: str | None = None) -> dict:
    from repro.obs import Metrics, Tracer
    from repro.obs.trace import NULL_TRACER

    tracer = Tracer(trace) if trace else NULL_TRACER
    mreg = Metrics(metrics) if metrics else None
    X_rows, y, meta = kat7(rows=rows)
    sess = GPSession(pop_size=pop, max_depth=depth, n_consts=8,
                     kernel=meta["kernel"], n_classes=meta["n_classes"],
                     backend="jnp", generations=gens,
                     tracer=tracer if trace else None, metrics=mreg)
    t0 = time.perf_counter()
    sess.ingest(X_rows, y)
    ingest_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    sess.init(key=jax.random.PRNGKey(seed))
    jax.block_until_ready(sess.state.fitness)
    init_s = time.perf_counter() - t0

    with tracer.span("bench:cold"):
        t0 = time.perf_counter()
        sess.evolve_block(gens)  # includes compile
        jax.block_until_ready(sess.state.fitness)
        compile_and_run_s = time.perf_counter() - t0

    sess.init(key=jax.random.PRNGKey(seed))
    with tracer.span("bench:warm"):
        t0 = time.perf_counter()
        _, history = sess.evolve_block(gens)
        jax.block_until_ready(history)
        run_s = time.perf_counter() - t0

    # fold the warm block's device telemetry stream into stats (one
    # extra sync, OUTSIDE the timed regions)
    st = sess.absorb_block_telemetry()
    rec = {
        "bench": "loop",
        "backend": "jnp",
        "pop": pop,
        "rows": rows,
        "depth": depth,
        "generations": gens,
        "block_dispatches": 1,
        "host_syncs_per_block": 1,
        "ingest_s": round(ingest_s, 4),
        "init_s": round(init_s, 4),
        "warm_s": round(run_s, 4),
        "cold_s": round(compile_and_run_s, 4),
        "generations_per_sec": round(gens / run_s, 4),
        "rows_evals_per_sec": round(gens * pop * rows / run_s, 1),
        "trees_rows_per_sec": round(gens * pop * rows / run_s, 1),
        "cache_hit_rate": round(st["cache_hit_rate"], 4),
        "cache_hits": st["cache_hits"],
        "cache_queries": st["cache_queries"],
        "tree_evals": st["tree_evals"],
        "jax": jax.__version__,
        "device": jax.devices()[0].platform,
        "machine": platform.machine(),
    }
    if trace:
        tracer.save()
    if mreg is not None:
        mreg.close()
    return rec


def bench_islands(*, pop: int = POP, rows: int = ROWS, gens: int = GENS,
                  depth: int = 5, seed: int = 0, islands: int = 4) -> dict:
    """1 island of `pop` trees vs `islands` heterogeneous islands of
    `pop // islands` — same total trees, same data, same generations —
    each timed as one warm evolution block. The heterogeneous variant
    spreads exploration/exploitation mixes across islands and ring-
    migrates elites every 3 generations."""
    X_rows, y, meta = kat7(rows=rows)
    mixes = (OperatorMix(),  # Table 2 baseline
             OperatorMix(0.05, 0.05, 0.05, 0.85),  # crossover-heavy
             OperatorMix(0.10, 0.30, 0.30, 0.30),  # mutation-heavy
             OperatorMix(0.30, 0.10, 0.10, 0.50))  # reproduction-heavy
    variants = {}
    for n_isl in (1, islands):
        kw = dict(islands=n_isl)
        if n_isl > 1:
            kw.update(migrate_every=3, migrate_k=2,
                      island_mixes=mixes[:n_isl],
                      island_tourn_sizes=tuple(4 + 3 * i for i in range(n_isl)))
        sess = GPSession(pop_size=pop // n_isl, max_depth=depth, n_consts=8,
                         kernel=meta["kernel"], n_classes=meta["n_classes"],
                         backend="jnp", generations=gens, **kw)
        sess.ingest(X_rows, y)
        sess.init(key=jax.random.PRNGKey(seed))
        sess.evolve_block(gens)  # compile
        jax.block_until_ready(sess.state.fitness)
        sess.init(key=jax.random.PRNGKey(seed))
        t0 = time.perf_counter()
        _, history = sess.evolve_block(gens)
        jax.block_until_ready(history)
        run_s = time.perf_counter() - t0
        variants[f"islands_{n_isl}"] = {
            "islands": n_isl,
            "pop_per_island": pop // n_isl,
            "warm_s": round(run_s, 4),
            "generations_per_sec": round(gens / run_s, 4),
            "best_fitness": float(jax.numpy.min(sess.state.best_fitness)),
        }
    return {
        "bench": "islands",
        "backend": "jnp",
        "total_pop": pop,
        "rows": rows,
        "depth": depth,
        "generations": gens,
        "topology": "ring",
        **variants,
        "jax": jax.__version__,
        "device": jax.devices()[0].platform,
        "machine": platform.machine(),
    }


def bench_service(*, pop: int = 64, rows: int = 96, gens: int = GENS,
                  depth: int = 5, seed: int = 0, n_jobs: int = 8,
                  slots: int = 4) -> dict:
    """`n_jobs` small heterogeneous jobs (ragged rows, mixed kernels,
    unequal budgets) packed into `slots` islands by GPService vs the
    same jobs as back-to-back solo islands=1 sessions. The service side
    compiles ONE program; each distinct solo dataset shape compiles its
    own — that per-job compile is exactly the cost packing removes, so
    both wall times include compilation."""
    import numpy as np

    from repro.service import GPService, JobSpec

    r = np.random.RandomState(seed)
    kernels = ("r", "mse", "pearson")
    jobs = []
    for i in range(n_jobs):
        n_rows = int(r.randint(rows // 2, rows + 1))
        X = r.randn(n_rows, 3).astype(np.float32)
        y = (X[:, 0] * X[:, 1] + np.sin(X[:, 0])).astype(np.float32)
        jobs.append(JobSpec(X, y, kernel=kernels[i % len(kernels)],
                            generations=gens + 2 * (i % 3), seed=i,
                            name=f"bench-{i}"))

    svc = GPService(slots=slots, pop_size=pop, max_depth=depth,
                    n_features=3, data_cap=rows, block_size=gens)
    handles = [svc.submit(j) for j in jobs]
    t0 = time.perf_counter()
    svc.run()
    service_s = time.perf_counter() - t0
    assert all(h.status == "done" for h in handles)

    t0 = time.perf_counter()
    solo_best = []
    for j in jobs:
        sess = GPSession(pop_size=pop, max_depth=depth, n_consts=8,
                         kernel=j.kernel, backend="jnp",
                         generations=j.generations)
        sess.ingest(j.X, j.y)
        sess.init(key=jax.random.PRNGKey(j.seed))
        sess.evolve_block(j.generations)
        jax.block_until_ready(sess.state.fitness)
        solo_best.append(float(jax.numpy.min(sess.state.best_fitness)))
    solo_s = time.perf_counter() - t0

    total_gens = sum(j.generations for j in jobs)
    return {
        "bench": "service",
        "backend": "jnp",
        "n_jobs": n_jobs,
        "slots": slots,
        "pop": pop,
        "data_cap": rows,
        "depth": depth,
        "total_generations": total_gens,
        "service_s": round(service_s, 4),
        "service_blocks": svc.stats["blocks"],
        "service_compiles": svc.stats["compiles"],
        "solo_s": round(solo_s, 4),
        "solo_sessions": n_jobs,
        "speedup": round(solo_s / service_s, 3),
        "job_gens_per_sec": round(total_gens / service_s, 4),
        "jax": jax.__version__,
        "device": jax.devices()[0].platform,
        "machine": platform.machine(),
    }


def bench_eval(*, gens: int = GENS, seed: int = 0, impl: str = "pallas",
               **_ignored) -> dict:
    """Tree vs postfix fused-kernel throughput at several P×N×D points.

    The SAME ramped population is scored through the heap level-sweep
    kernel and — converted with `trees.heap_to_postfix` — the postfix
    stack kernel, so the trees·rows/sec ratio isolates the genome
    representation (identical semantics, pinned bitwise by tests). Each
    point reports both kernels' best-of-several warm runs interleaved
    (robust to background load); `postfix_speedup_headline` is the
    P>=512, depth-5 (N=63) point the perf trajectory tracks.

    Each point also times the exact-tier subexpression dedup
    (docs/genomes.md) on a DUPLICATE-HEAVY population — 8 distinct
    genomes tiled to `pop`, the shape a converged GP population takes —
    dedup-off vs dedup-on (tight `dedup_cap=512` unique table)
    interleaved, on the jnp impl: the Pallas path runs in interpret
    mode off-TPU, where emulation overhead would swamp the kernel, so
    the jnp pair is the honest CPU measurement. `dedup_speedup` rides
    each cell with the population's measured duplicate-subtree rate;
    `dedup_speedup_headline` is the P=1024, N=63, D=32k point."""
    import dataclasses

    import numpy as np

    from repro.core import eval as core_eval
    from repro.core.fitness import FitnessSpec
    from repro.core.trees import TreeSpec, generate_population, heap_to_postfix
    from repro.kernels import ops as kops

    points = ((128, 4, 8_192), (512, 5, 16_384), (1024, 5, 32_768))
    rounds = max(3, min(7, gens))
    fit_spec = FitnessSpec(kernel="r")
    dedup_cap = 512
    cells = []
    headline = None
    dedup_headline = None
    for pop, depth, rows in points:
        spec_t = TreeSpec(max_depth=depth, n_features=4, n_consts=8)
        spec_p = dataclasses.replace(spec_t, genome="postfix")
        op_t, arg_t = generate_population(jax.random.PRNGKey(seed), pop, spec_t)
        op_p, arg_p = heap_to_postfix(op_t, arg_t)
        # duplicate-heavy population: 8 distinct genomes tiled to pop
        op_d = jax.numpy.tile(op_p[:8], (pop // 8, 1))
        arg_d = jax.numpy.tile(arg_p[:8], (pop // 8, 1))
        r = np.random.RandomState(seed)
        X = jax.numpy.asarray(r.randn(4, rows).astype(np.float32))
        y = jax.numpy.asarray(r.randn(rows).astype(np.float32))
        const = spec_t.const_table()
        runs = {
            "tree": jax.jit(lambda s=spec_t, o=op_t, a=arg_t: kops.fitness(
                o, a, X, y, const, s, fit_spec, impl=impl)),
            "postfix": jax.jit(lambda s=spec_p, o=op_p, a=arg_p: kops.fitness(
                o, a, X, y, const, s, fit_spec, impl=impl)),
            "dedup_off": jax.jit(lambda: kops.fitness(
                op_d, arg_d, X, y, const, spec_p, fit_spec, impl="jnp")),
            "dedup_on": jax.jit(lambda: kops.fitness(
                op_d, arg_d, X, y, const, spec_p, fit_spec, impl="jnp",
                dedup="exact", dedup_cap=dedup_cap)),
        }
        uniq, saved = (int(v) for v in core_eval.dedup_stats(
            op_d, arg_d, spec_p, dedup_cap))
        cell = {"pop": pop, "depth": depth, "nodes": spec_t.num_nodes,
                "rows": rows, "dedup_cap": dedup_cap,
                "unique_subtrees": uniq, "subtree_evals_saved": saved,
                "duplicate_rate": round(saved / (saved + uniq), 4)}
        best = {}
        for tag, f in runs.items():
            jax.block_until_ready(f())  # compile
            best[tag] = float("inf")
        for _ in range(rounds):  # interleaved: background load hits both
            for tag, f in runs.items():
                t0 = time.perf_counter()
                jax.block_until_ready(f())
                best[tag] = min(best[tag], time.perf_counter() - t0)
        for tag, dt in best.items():
            cell[f"{tag}_s"] = round(dt, 5)
            cell[f"{tag}_trees_rows_per_sec"] = round(pop * rows / dt, 1)
        cell["postfix_speedup"] = round(best["tree"] / best["postfix"], 3)
        cell["dedup_speedup"] = round(best["dedup_off"] / best["dedup_on"], 3)
        cells.append(cell)
        if headline is None and pop >= 512 and spec_t.num_nodes >= 63:
            headline = cell["postfix_speedup"]
        if pop >= 1024 and spec_t.num_nodes >= 63:
            dedup_headline = cell["dedup_speedup"]
    return {
        "bench": "eval",
        "backend": impl,
        "kernel": "r",
        "rounds": rounds,
        "points": cells,
        "postfix_speedup_headline": headline,
        "dedup_speedup_headline": dedup_headline,
        "dedup_impl": "jnp",
        "jax": jax.__version__,
        "device": jax.devices()[0].platform,
        "machine": platform.machine(),
    }


def bench_stream(*, pop: int = 64, rows: int = 5_500_000, gens: int = 3,
                 depth: int = 4, seed: int = 0, chunk_rows: int = 262_144,
                 feats: int = 8) -> dict:
    """Streaming chunked fitness at the paper's 5.5M-data-point scale.

    Evolves over a synthetic `datasets.stream_rows` regression stream
    with `GPSession.ingest(stream=..., chunk_rows=...)` — peak device
    footprint is ONE `[feats, chunk_rows]` chunk no matter how many rows
    stream past. When the dataset is small enough to materialize
    (`rows <= 2M`), the same rows are also evaluated monolithically and
    the best-fitness history compared, so the artifact doubles as a
    chunking-parity check at bench scale."""
    import numpy as np

    from repro.data.datasets import stream_rows

    source = stream_rows(rows=rows, feats=feats, seed=seed)
    sess = GPSession(pop_size=pop, max_depth=depth, n_consts=8, kernel="mse",
                     backend="jnp", generations=gens)
    sess.ingest(stream=source, chunk_rows=chunk_rows)
    sess.init(key=jax.random.PRNGKey(seed))
    sess.step()  # compile + first full pass (n_rows discovered here)
    t0 = time.perf_counter()
    sess.evolve(gens)
    run_s = time.perf_counter() - t0

    rec = {
        "bench": "stream",
        "backend": "jnp",
        "pop": pop,
        "rows": rows,
        "feats": feats,
        "chunk_rows": chunk_rows,
        "n_chunks": sess._stream.n_chunks,
        "depth": depth,
        "generations": gens,
        "warm_s": round(run_s, 4),
        "generations_per_sec": round(gens / run_s, 4),
        "rows_evals_per_sec": round(gens * pop * rows / run_s, 1),
        "best_fitness": float(sess.best_fitness),
        "jax": jax.__version__,
        "device": jax.devices()[0].platform,
        "machine": platform.machine(),
    }
    if rows <= 2_000_000:
        X = np.concatenate([b[0] for b in source()])
        y = np.concatenate([b[1] for b in source()])
        mono = GPSession(pop_size=pop, max_depth=depth, n_consts=8,
                         kernel="mse", backend="jnp", generations=gens)
        mono.ingest(X, y)
        mono.init(key=jax.random.PRNGKey(seed))
        mono.step()
        t0 = time.perf_counter()
        mono.evolve(gens)
        mono_s = time.perf_counter() - t0
        diff = max(abs(a - b) / max(abs(a), 1e-9)
                   for a, b in zip(sess.history, mono.history))
        rec.update(monolithic_s=round(mono_s, 4),
                   stream_overhead=round(run_s / mono_s, 3),
                   history_rel_diff=float(diff))
    return rec


BENCHES = {"loop": bench_loop, "islands": bench_islands,
           "service": bench_service, "eval": bench_eval,
           "stream": bench_stream}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="loop", choices=sorted(BENCHES))
    ap.add_argument("--pop", type=int, default=None)
    ap.add_argument("--rows", type=int, default=None)
    ap.add_argument("--gens", type=int, default=GENS)
    ap.add_argument("--chunk-rows", type=int, default=None,
                    help="stream bench: rows per fixed-shape chunk")
    ap.add_argument("--out", default=None)
    ap.add_argument("--trace", default=None,
                    help="loop bench: write a Chrome trace JSON here "
                         "(repro.obs — see docs/observability.md)")
    ap.add_argument("--metrics", default=None,
                    help="loop bench: append metrics JSONL here")
    args = ap.parse_args()
    kw = dict(gens=args.gens)
    if args.pop is not None:
        kw["pop"] = args.pop
    if args.rows is not None:
        kw["rows"] = args.rows
    if args.chunk_rows is not None:
        kw["chunk_rows"] = args.chunk_rows
    if args.bench == "loop":
        kw["trace"], kw["metrics"] = args.trace, args.metrics
    rec = BENCHES[args.bench](**kw)
    out = args.out or f"BENCH_{args.bench}.json"
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
