"""HLO-text cost model with while-loop trip-count accounting.

XLA's built-in `compiled.cost_analysis()` visits every while body ONCE
(tests/test_hlo_cost.py demonstrates it), so any scanned model — which is
every model here — under-reports FLOPs/bytes/collectives by the loop trip
count (88× for mistral's layer scan). This analyzer parses the
post-partitioning HLO text, where

  * every `while` op carries `backend_config={"known_trip_count":{"n":K}}`
    (jax scans always lower with static trip counts),
  * every shape is per-device,

and computes, with loops multiplied through (nested loops compose):

  flops             dot ops: 2 · prod(result dims) · prod(contract dims);
                    plus 1 flop/output-element for every arithmetic
                    instruction inside fused computations (captures
                    elementwise-dominated programs like the GP engine)
  bytes             HBM traffic proxy: 2 × result bytes (one write + one
                    read) of every materializing top-level op — fusion
                    internals are registers/VMEM, pure layout/convert ops
                    are assumed fused away on TPU (CPU float-normalization
                    would otherwise double-count every bf16 buffer)
  collectives       per-kind result bytes of all-gather / all-reduce /
                    reduce-scatter / all-to-all / collective-permute

The analyzer is validated against `cost_analysis()` on loop-free programs
(they agree on flops) and against hand-counts on scans.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
                "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%(?P<name>[^\s=]+)\s+=\s+(?P<type>.*?)\s+"
    r"(?P<op>[\w\-]+)\((?P<operands>.*?)\)(?P<rest>.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w\.\-]+)\s+\(.*\)\s+->")
_TRIP_RE = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "iota", "broadcast", "reshape"}

# pure data-movement/layout ops: fused into consumers on TPU — not counted
# as HBM materialization points, and zero flops
_LAYOUT_OPS = _SKIP_OPS | {"transpose", "slice", "pad", "concatenate",
                           "convert", "copy", "reverse", "copy-start",
                           "copy-done", "dynamic-slice"}


def _shape_elems_bytes(type_str: str):
    elems, nbytes = [], 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems.append((n, dt))
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


def _dims_of(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: dict = field(default_factory=dict)

    def __iadd__(self, other):
        self.flops += other.flops
        self.bytes += other.bytes
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + v
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k,
                    {kk: v * k for kk, v in self.collectives.items()})

    @property
    def collective_bytes(self) -> float:
        return sum(self.collectives.values())


class HloAnalyzer:
    def __init__(self, text: str, *, unknown_trip: int = 1):
        # `unknown_trip`: trip count charged to while loops that carry no
        # known_trip_count and whose condition holds no literal bound —
        # i.e. data-dependent loops (the postfix GP kernel's instruction
        # loop bounds itself by the tile's max program length at runtime).
        # Callers that know the true bound pass it here.
        self.computations = self._split(text)
        self.unknown_trip = unknown_trip
        self._memo: dict[str, Cost] = {}

    @staticmethod
    def _split(text: str):
        comps, cur, name = {}, None, None
        for line in text.splitlines():
            if not line.startswith(" ") and ("->" in line) and line.rstrip().endswith("{"):
                m = _COMP_RE.match(line.strip())
                if m:
                    name = m.group("name")
                    cur = []
                    comps[name] = cur
                    continue
            if line.startswith("}"):
                name, cur = None, None
                continue
            if cur is not None:
                cur.append(line)
        return comps

    # -- per-instruction costs ------------------------------------------------

    def _dot_flops(self, type_str, operands_types, rest):
        out_dims = _dims_of(type_str)
        out_elems = 1
        for d in out_dims:
            out_elems *= d
        lhs_dims = _dims_of(operands_types[0]) if operands_types else []
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
        contract = 1
        if m and lhs_dims:
            for i in (int(x) for x in m.group(1).split(",") if x):
                if i < len(lhs_dims):
                    contract *= lhs_dims[i]
        return 2.0 * out_elems * contract

    def _operand_types(self, comp_lines_types, operands_str):
        types = []
        for name in re.findall(r"%([\w\.\-]+)", operands_str):
            if name in comp_lines_types:
                types.append(comp_lines_types[name])
        return types

    def _fusion_flops(self, name: str) -> float:
        """Elementwise flops inside a fused computation: 1 flop per output
        element of each arithmetic instruction (+ dot formula for any
        fused dot). Cached per computation."""
        key = ("fusion", name)
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = 0.0
        flops = 0.0
        lines = self.computations.get(name, [])
        types: dict[str, str] = {}
        for line in lines:
            m = _INSTR_RE.match(line)
            if m:
                types[m.group("name")] = m.group("type")
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            op = m.group("op")
            if op in _LAYOUT_OPS or op in ("select", "compare", "fusion"):
                if op == "fusion":
                    cm = _CALLS_RE.search(m.group("rest"))
                    if cm:
                        flops += self._fusion_flops(cm.group(1))
                continue
            if op == "dot":
                opnds = self._operand_types(types, m.group("operands"))
                flops += self._dot_flops(m.group("type"), opnds, m.group("rest"))
                continue
            elems = 0
            for n, _dt in _shape_elems_bytes(m.group("type"))[0]:
                elems += n
            flops += elems
        self._memo[key] = flops
        return flops

    def analyze_computation(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()  # break cycles defensively
        lines = self.computations.get(name, [])
        # symbol table: instruction name -> type string
        types: dict[str, str] = {}
        for line in lines:
            m = _INSTR_RE.match(line)
            if m:
                types[m.group("name")] = m.group("type")
            else:
                pm = re.match(r"^\s+%?([\w\.\-]+)\s+=\s+(.*?)\s+parameter\(", line)
                if pm:
                    types[pm.group(1)] = pm.group(2)

        total = Cost()
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            op = m.group("op")
            type_str = m.group("type")
            rest = m.group("rest")
            _, out_bytes = _shape_elems_bytes(type_str)

            if op == "while":
                body = _BODY_RE.search(rest)
                trip = 1
                tm = _TRIP_RE.search(rest)
                if tm:
                    trip = int(tm.group(1))
                else:
                    cond = _COND_RE.search(rest)
                    if cond:
                        consts = [int(c) for c in re.findall(
                            r"constant\((\d+)\)", "\n".join(
                                self.computations.get(cond.group(1), [])))]
                        trip = max(consts) if consts else self.unknown_trip
                if body:
                    total += self.analyze_computation(body.group(1)).scaled(trip)
                continue
            if op in ("call", "async-start"):
                cm = re.search(r"to_apply=%?([\w\.\-]+)", rest) or _CALLS_RE.search(rest)
                if cm:
                    total += self.analyze_computation(cm.group(1))
                continue
            if op == "conditional":
                branches = re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                      r"(?:true|false)_computation=%?([\w\.\-]+))", rest)
                names = []
                for a, b in branches:
                    names += [x.strip().lstrip("%") for x in a.split(",") if x] if a else [b]
                for n in names:
                    if n:
                        total += self.analyze_computation(n)
                continue

            c = Cost()
            if op == "dot":
                opnds = self._operand_types(types, m.group("operands"))
                c.flops += self._dot_flops(type_str, opnds, rest)
            elif op == "fusion":
                cm = _CALLS_RE.search(rest)
                if cm:
                    c.flops += self._fusion_flops(cm.group(1))
            for kind in COLLECTIVES:
                if op == kind or op == kind + "-start":
                    c.collectives[kind] = c.collectives.get(kind, 0.0) + out_bytes
            # memory proxy: one write + one read per materialization point
            if op not in _LAYOUT_OPS and not op.endswith("-done"):
                c.bytes += 2.0 * out_bytes
            total += c
        self._memo[name] = total
        return total

    def entry_cost(self) -> Cost:
        # the ENTRY computation is the one referenced by nothing else; XLA
        # puts it last — find by name heuristic then fallback to largest
        for name in self.computations:
            if name.startswith("main") or ".main" in name:
                return self.analyze_computation(name)
        # fallback: last computation in file order
        last = list(self.computations)[-1]
        return self.analyze_computation(last)


def analyze_hlo_text(text: str, *, unknown_trip: int = 1) -> dict:
    a = HloAnalyzer(text, unknown_trip=unknown_trip)
    c = a.entry_cost()
    return {"flops": c.flops, "bytes": c.bytes,
            "collectives": dict(c.collectives),
            "collective_bytes": c.collective_bytes}


def analyze_file(path: str, *, unknown_trip: int = 1) -> dict:
    with open(path) as f:
        return analyze_hlo_text(f.read(), unknown_trip=unknown_trip)


if __name__ == "__main__":
    import sys

    print(json.dumps(analyze_file(sys.argv[1]), indent=1))
