"""Benchmark harness reproducing the paper's measurement axes.

One benchmark per paper table/figure (DESIGN.md §6):

  Fig. 1  Kepler  (9×2)      scalar vs vectorized, tiny data
  Fig. 2  Iris   (150×4)     scalar vs vectorized
  Fig. 3  KAT-7  (10k×9)     the 875× axis (40-CPU_PP vs TF in the paper)
  Fig. 4  LIGO   (4k×1373)   millions of data points
  Tab. 4 / Fig. 5            cross-dataset platform matrix

Platforms here map the paper's six configurations onto this container:
  scalar      = core/scalar_eval.py  (paper: 1-CPU_SP — SymPy, per-point)
  jnp         = vectorized XLA path  (paper: *-CPU_TF)
  pallas      = fused kernel, interpret mode (paper: GPU_TF; on real TPU
                this is the compiled-kernel column)

Methodology follows §3.2–3.3: identical GP parameters (Table 2) across
platforms, wall time for a full run of G generations. The scalar baseline
runs reduced generations and extrapolates linearly — the same `*`
extrapolation the paper applies to its own Table 4 cells (48 h entries).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import GPConfig, TreeSpec, FitnessSpec, evolve_step, init_state
from repro.core import evolve as ev
from repro.core import primitives as prim
from repro.core.scalar_eval import fitness_scalar
from repro.data.datasets import BY_NAME
from repro.data.loader import feature_major

TABLE2 = dict(pop_size=100, tourn_size=10, generations=30)


def _cfg(name, meta, F, impl, pop=None, depth=5):
    fset = prim.KITCHEN_SINK if meta["kernel"] == "r" else prim.CLASSIFY_SET
    return GPConfig(
        name=f"karoo-{name}", pop_size=pop or TABLE2["pop_size"],
        tree_spec=TreeSpec(max_depth=depth, n_features=F, n_consts=8, fn_set=fset),
        fitness=FitnessSpec(meta["kernel"], n_classes=meta.get("n_classes", 3)),
        tourn_size=TABLE2["tourn_size"], generations=TABLE2["generations"],
        eval_impl=impl)


def time_vectorized(name: str, impl: str, generations: int, *, pop=None,
                    seed=0) -> float:
    """Wall seconds for `generations` full GP generations (jit warm)."""
    X_rows, y, meta = BY_NAME[name]()
    cfg = _cfg(name, meta, X_rows.shape[1], impl, pop)
    X = jax.numpy.asarray(feature_major(X_rows))
    yj = jax.numpy.asarray(np.asarray(y, np.float32))
    state = init_state(cfg, jax.random.PRNGKey(seed))
    state = evolve_step(cfg, state, X, yj)  # compile outside the clock
    jax.block_until_ready(state.fitness)
    t0 = time.perf_counter()
    for _ in range(generations):
        state = evolve_step(cfg, state, X, yj)
    jax.block_until_ready(state.fitness)
    return time.perf_counter() - t0


def time_scalar(name: str, generations: int, *, seed=0,
                max_rows: int | None = None) -> tuple[float, int, int]:
    """Wall seconds for `generations` generations with the paper-baseline
    scalar interpreter doing evaluation (selection/ops still negligible).
    Returns (seconds, rows_used, rows_total)."""
    X_rows, y, meta = BY_NAME[name]()
    rows_total = X_rows.shape[0]
    if max_rows and rows_total > max_rows:
        X_rows, y = X_rows[:max_rows], y[:max_rows]
    cfg = _cfg(name, meta, X_rows.shape[1], "jnp")
    state = init_state(cfg, jax.random.PRNGKey(seed))
    consts = np.asarray(cfg.tree_spec.const_table())
    key = jax.random.PRNGKey(seed + 1)
    op, arg = np.asarray(state.op), np.asarray(state.arg)
    t0 = time.perf_counter()
    for g in range(generations):
        fit = fitness_scalar(op, arg, X_rows, y, consts,
                             kernel=cfg.fitness.kernel,
                             n_classes=cfg.fitness.n_classes)
        key, k2 = jax.random.split(key)
        new_op, new_arg = ev.next_generation(
            k2, jax.numpy.asarray(op), jax.numpy.asarray(arg),
            jax.numpy.asarray(fit), cfg.tree_spec, cfg.mix, cfg.tourn_size, 1)
        op, arg = np.asarray(new_op), np.asarray(new_arg)
    return time.perf_counter() - t0, X_rows.shape[0], rows_total


def bench_figure(name: str, *, scalar_gens: int, vector_gens: int,
                 scalar_max_rows=None, impls=("jnp", "pallas")) -> dict:
    """One figure: scalar baseline + each vectorized platform, normalized to
    full-run (30 generations, full rows) wall time."""
    G = TABLE2["generations"]
    t_s, rows_used, rows_total = time_scalar(name, scalar_gens,
                                             max_rows=scalar_max_rows)
    scalar_full = t_s * (G / scalar_gens) * (rows_total / rows_used)
    out = {"dataset": name, "scalar_s_extrapolated": scalar_full,
           "scalar_measured_s": t_s, "scalar_gens": scalar_gens,
           "scalar_rows": rows_used}
    for impl in impls:
        t_v = time_vectorized(name, impl, vector_gens)
        full = t_v * (G / vector_gens)
        out[f"{impl}_s"] = full
        out[f"speedup_{impl}"] = scalar_full / full
    return out
