"""Benchmark harness reproducing the paper's measurement axes.

One benchmark per paper table/figure (DESIGN.md §6):

  Fig. 1  Kepler  (9×2)      scalar vs vectorized, tiny data
  Fig. 2  Iris   (150×4)     scalar vs vectorized
  Fig. 3  KAT-7  (10k×9)     the 875× axis (40-CPU_PP vs TF in the paper)
  Fig. 4  LIGO   (4k×1373)   millions of data points
  Tab. 4 / Fig. 5            cross-dataset platform matrix

The paper's six platform configurations are the EvalBackend registry
(`repro.gp.backends`), so every cell is the SAME code path —
`GPSession(backend=...)` — timed per generation:

  scalar      = core/scalar_eval.py  (paper: 1-CPU_SP — SymPy, per-point)
  jnp         = vectorized XLA path  (paper: *-CPU_TF)
  pallas      = fused kernel, interpret mode (paper: GPU_TF; on real TPU
                this is the compiled-kernel column)

Methodology follows §3.2–3.3: identical GP parameters (Table 2) across
platforms, wall time for a full run of G generations. The scalar baseline
runs reduced generations/rows and extrapolates linearly — the same `*`
extrapolation the paper applies to its own Table 4 cells (48 h entries).
"""
from __future__ import annotations

import time

import jax

from repro.data.datasets import BY_NAME
from repro.gp import GPSession, get_backend

TABLE2 = dict(pop_size=100, tourn_size=10, generations=30)


def make_session(name: str, backend: str, *, pop=None, depth: int = 5,
                 max_rows=None) -> GPSession:
    """Table-2 configured session on a paper dataset — one front door for
    every (dataset × platform) cell."""
    return GPSession.from_dataset(
        name, max_rows=max_rows, backend=backend,
        pop_size=pop or TABLE2["pop_size"], max_depth=depth, n_consts=8,
        tourn_size=TABLE2["tourn_size"], generations=TABLE2["generations"])


def time_backend(name: str, backend: str, generations: int, *, pop=None,
                 max_rows=None, seed=0) -> tuple[float, int, int]:
    """Wall seconds for `generations` full GP generations on `backend`.
    Jitted platforms run the whole span as ONE device-resident evolution
    block (`lax.scan`), compiled outside the clock — the timed number is
    pure on-device generation throughput with a single host sync, which
    is how `GPSession.evolve()` actually drives production runs. The
    scalar baseline steps on the host as the paper's 1-CPU_SP did.
    Returns (s, rows_used, rows_total)."""
    rows_total = BY_NAME[name]()[0].shape[0]
    sess = make_session(name, backend, pop=pop, max_rows=max_rows)
    rows_used = sess.n_rows
    sess.init(key=jax.random.PRNGKey(seed))
    if get_backend(backend).jittable:
        sess.evolve_block(generations)  # compile outside the clock
        jax.block_until_ready(sess.state.fitness)
        sess.init(key=jax.random.PRNGKey(seed))
        t0 = time.perf_counter()
        _, history = sess.evolve_block(generations)
        jax.block_until_ready(history)
        return time.perf_counter() - t0, rows_used, rows_total
    t0 = time.perf_counter()
    for _ in range(generations):
        sess.step()
    jax.block_until_ready(sess.state.op)  # last gen's async selection work
    return time.perf_counter() - t0, rows_used, rows_total


def bench_figure(name: str, *, scalar_gens: int, vector_gens: int,
                 scalar_max_rows=None, impls=("jnp", "pallas")) -> dict:
    """One figure: scalar baseline + each vectorized platform, normalized to
    full-run (30 generations, full rows) wall time."""
    G = TABLE2["generations"]
    t_s, rows_used, rows_total = time_backend(name, "scalar", scalar_gens,
                                              max_rows=scalar_max_rows)
    scalar_full = t_s * (G / scalar_gens) * (rows_total / rows_used)
    out = {"dataset": name, "scalar_s_extrapolated": scalar_full,
           "scalar_measured_s": t_s, "scalar_gens": scalar_gens,
           "scalar_rows": rows_used}
    for impl in impls:
        t_v, _, _ = time_backend(name, impl, vector_gens)
        full = t_v * (G / vector_gens)
        out[f"{impl}_s"] = full
        out[f"speedup_{impl}"] = scalar_full / full
    return out
