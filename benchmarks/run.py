"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV — us_per_call is wall time per GP
generation (the paper's unit is wall time per 30-generation run; we report
per-generation so rows are comparable across datasets), derived is the
scalar→vectorized speedup on that dataset (the paper's headline axis:
2×/15×/875×), or the roofline fraction for dry-run rows.

Scalar baselines run reduced generations/rows and extrapolate — exactly
the paper's own `*` methodology in Table 4 (its 1-CPU_SP KAT-7 cell is an
estimate too: "roughly 160 hours").
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))))

from benchmarks.paper_bench import TABLE2, bench_figure  # noqa: E402

G = TABLE2["generations"]


def _emit(name, seconds_per_run, derived):
    us_per_gen = seconds_per_run / G * 1e6
    print(f"{name},{us_per_gen:.1f},{derived}")


def bench_fig1_kepler(results):
    r = bench_figure("kepler", scalar_gens=G, vector_gens=G)
    results["kepler"] = r
    _emit("fig1_kepler_scalar", r["scalar_s_extrapolated"], "baseline(1-CPU_SP)")
    _emit("fig1_kepler_jnp", r["jnp_s"], f"speedup={r['speedup_jnp']:.1f}x")
    _emit("fig1_kepler_pallas", r["pallas_s"], f"speedup={r['speedup_pallas']:.1f}x")


def bench_fig2_iris(results):
    r = bench_figure("iris", scalar_gens=5, vector_gens=G)
    results["iris"] = r
    _emit("fig2_iris_scalar", r["scalar_s_extrapolated"], "baseline(1-CPU_SP)")
    _emit("fig2_iris_jnp", r["jnp_s"], f"speedup={r['speedup_jnp']:.1f}x")
    _emit("fig2_iris_pallas", r["pallas_s"], f"speedup={r['speedup_pallas']:.1f}x")


def bench_fig3_kat7(results):
    r = bench_figure("kat7", scalar_gens=1, vector_gens=10, scalar_max_rows=500)
    results["kat7"] = r
    _emit("fig3_kat7_scalar", r["scalar_s_extrapolated"], "baseline(extrapolated)")
    _emit("fig3_kat7_jnp", r["jnp_s"], f"speedup={r['speedup_jnp']:.0f}x")
    _emit("fig3_kat7_pallas", r["pallas_s"], f"speedup={r['speedup_pallas']:.0f}x")


def bench_fig4_ligo(results):
    r = bench_figure("ligo", scalar_gens=1, vector_gens=2, scalar_max_rows=40,
                     impls=("jnp",))
    results["ligo"] = r
    _emit("fig4_ligo_scalar", r["scalar_s_extrapolated"], "baseline(extrapolated)")
    _emit("fig4_ligo_jnp", r["jnp_s"], f"speedup={r['speedup_jnp']:.0f}x")


def bench_table4(results):
    """Cross-dataset matrix (Table 4 / Fig. 5): rows already measured."""
    for name, r in results.items():
        cols = [f"scalar={r['scalar_s_extrapolated']:.2f}s",
                f"jnp={r.get('jnp_s', float('nan')):.2f}s"]
        if "pallas_s" in r:
            cols.append(f"pallas={r['pallas_s']:.2f}s")
        _emit(f"table4_{name}", r.get("jnp_s", 0.0), ";".join(cols))


def bench_scaling():
    """Beyond-paper: vectorized-engine scaling in population size (the
    paper scales data; production GP also scales populations)."""
    from benchmarks.paper_bench import time_backend

    base = None
    for pop in (100, 400, 1600):
        t = time_backend("kat7", "jnp", 3, pop=pop)[0] / 3
        base = base or t
        print(f"scaling_kat7_pop{pop},{t*1e6:.1f},"
              f"work_x={pop/100:.0f};time_x={t/base:.2f}")


def bench_roofline():
    """§Roofline summary rows from the dry-run artifacts (if present)."""
    path = "benchmarks/artifacts/roofline.json"
    if not os.path.exists(path):
        art = "benchmarks/artifacts/dryrun"
        if os.path.isdir(art) and any(f.endswith("_sp.json") for f in os.listdir(art)):
            from benchmarks.roofline import build_table
            build_table(art, path)
        else:
            print("roofline,0,skipped(no dryrun artifacts)")
            return
    rows = json.load(open(path))
    for r in rows:
        if r.get("status") != "ok":
            continue
        bound = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        print(f"roofline_{r['arch']}_{r.get('shape','')},{bound*1e6:.1f},"
              f"dom={r['dominant']};roofline={100*r['roofline_fraction']:.1f}%")


def main() -> None:
    results = {}
    bench_fig1_kepler(results)
    bench_fig2_iris(results)
    bench_fig3_kat7(results)
    bench_fig4_ligo(results)
    bench_table4(results)
    bench_scaling()
    bench_roofline()
    os.makedirs("benchmarks/artifacts", exist_ok=True)
    with open("benchmarks/artifacts/paper_bench.json", "w") as f:
        json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
