"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

For every (arch × shape) cell compiled on the single-pod 16×16 mesh:

  compute term    = HLO_FLOPs_per_device / 197e12        (bf16 MXU peak)
  memory term     = HLO_bytes_per_device / 819e9         (HBM bandwidth)
  collective term = collective_bytes_per_device / 50e9   (ICI per link)

FLOPs/bytes/collective bytes come from benchmarks/hlo_cost.py (trip-count-
aware; XLA's cost_analysis undercounts every scanned loop). The dominant
term ≈ the step-time lower bound; MODEL_FLOPS/HLO_FLOPs shows how much of
the compiled compute is "useful" (remat, padding, dispatch waste).

CPU-backend caveats (documented per-cell where they bite):
  * bf16 dots are float-normalized to f32 on CPU — flops unaffected, but
    memory bytes of dot operands read ~2× larger than TPU-true. We report
    a bf16-corrected memory term alongside the raw one.
"""
from __future__ import annotations

import glob
import json
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _REPO)
sys.path.insert(0, os.path.join(_REPO, "src"))
from benchmarks.hlo_cost import analyze_file, analyze_hlo_text  # noqa: E402

PEAK_FLOPS = 197e12  # bf16 / chip (v5e)
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / ICI link
CHIPS = 256  # single pod

SHAPE_TOKENS = {"train_4k": (4096, 256), "prefill_32k": (32768, 32),
                "decode_32k": (1, 128), "long_500k": (1, 1)}

# active params (B) per arch — 6·N·D numerator (decode/prefill use 2·N·D)
ACTIVE_PARAMS = {
    "qwen1.5-32b": 32.5e9, "gemma-2b": 2.5e9, "mistral-large-123b": 122.6e9,
    "minitron-8b": 8.3e9, "granite-moe-3b-a800m": 1.0e9,
    "qwen3-moe-30b-a3b": 3.3e9, "whisper-medium": 0.76e9,
    "mamba2-370m": 0.37e9, "jamba-1.5-large-398b": 94e9,
    "llama-3.2-vision-90b": 88e9,
}


def model_flops(arch: str, shape: str) -> float:
    """Per-device useful FLOPs (6ND train / 2ND forward), GP cells
    handled separately."""
    seq, batch = SHAPE_TOKENS[shape]
    n = ACTIVE_PARAMS[arch]
    mult = 6.0 if shape == "train_4k" else 2.0
    return mult * n * seq * batch / CHIPS


def gp_model_flops(pop: int, rows: int, nodes: int = 63) -> float:
    """GP useful work: one primitive application per (tree-node × point)."""
    return pop * nodes * rows / CHIPS


def analyze_cell(json_path: str) -> dict | None:
    with open(json_path) as f:
        rec = json.load(f)
    if rec.get("status") != "ok":
        return rec
    hlo_path = json_path.replace(".json", ".hlo.txt")
    if os.path.exists(hlo_path):
        cost = analyze_file(hlo_path)
    else:
        cost = {"flops": rec["flops"], "bytes": rec["bytes_accessed"],
                "collective_bytes": sum(rec["collective_bytes"].values()),
                "collectives": rec["collective_bytes"]}
    arch, shape = rec["arch"], rec.get("shape", "")
    t_c = cost["flops"] / PEAK_FLOPS
    t_m = cost["bytes"] / HBM_BW
    t_coll = cost["collective_bytes"] / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    if arch in ACTIVE_PARAMS and shape in SHAPE_TOKENS:
        mf = model_flops(arch, shape)
    elif arch.startswith("karoo"):
        import re as _re
        mpop = _re.search(r"pop(\d+)", shape)
        mrows = _re.search(r"rows(\d+)", shape)
        mf = (gp_model_flops(int(mpop.group(1)), int(mrows.group(1)))
              if mpop and mrows else 0.0)
    else:
        mf = 0.0
    bound = max(terms.values())
    rec.update({
        "hlo_flops": cost["flops"], "hlo_bytes": cost["bytes"],
        "hlo_collective_bytes": cost["collective_bytes"],
        "hlo_collectives": cost.get("collectives", {}),
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": (mf / cost["flops"]) if cost["flops"] else 0.0,
        "roofline_fraction": (mf / PEAK_FLOPS) / bound if bound else 0.0,
    })
    return rec


def gp_eval_cost(pop: int = 512, rows: int = 16384, max_depth: int = 5,
                 n_features: int = 4, kernel: str = "r",
                 dedup_cap: int | None = None,
                 out_path: str | None = "benchmarks/artifacts/gp_eval_cost.json"):
    """Bytes/FLOPs of one full-population fitness evaluation — the eval
    work of one generation — compiled live for both genome forms.

    Lowers `kernels.ops.fitness` for the tree (level-sweep) and postfix
    (stack-interpreter) kernels at the same (pop × rows × depth) point and
    runs the trip-count-aware HLO cost model on each compiled module. The
    postfix instruction loop is data-dependent (`jnp.max(lens)` per pop
    tile), so its `while` carries no known_trip_count — we charge it at
    the population's true max program length via `unknown_trip`, i.e. the
    cost of the *longest* tile; length-sorted tiles of short programs exit
    earlier, so the postfix bytes/FLOPs reported here are an upper bound.

    "Useful" work is one primitive application per (active node × data
    point): identical for both forms — they encode the same trees — which
    is what makes useful_ratio the apples-to-apples dispatch-waste metric
    (the tree kernel sweeps all N heap slots; postfix executes only live
    instructions).

    Three more cells cost the exact-tier subexpression dedup
    (docs/genomes.md) on a DUPLICATE-HEAVY population (8 distinct
    genomes tiled to `pop`): `postfix-dup` is the plain jnp evaluator;
    `postfix-dedup` the ENGAGED dedup eval — one interpreter pass over
    the `dedup_cap`-row unique table + row gather + epilogue — lowered
    without its overflow fallback branch (the compiled artifact carries
    both `cond` arms but executes one; the cost model sums branches, so
    the fallback is lowered out here); `dedup-plan` the plan build
    (signature pack + sort + schedule scatter), costed separately
    because it is int32 bookkeeping on `[P, N]` genomes — independent
    of `rows`, so it amortizes to nothing as the dataset grows — and
    because its sort `while`s carry no trip bound, so the `unknown_trip`
    heuristic (sized for the eval loop) over-charges them. The
    `dedup_over_plain_flops` summary is the eval-path ratio — the
    per-generation FLOP reduction the dedup buys, → `cap/pop` of the
    plain interpreter work as duplication saturates."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.core import eval as core_eval
    from repro.core.fitness import FitnessSpec
    from repro.core.trees import TreeSpec, generate_population, heap_to_postfix
    from repro.kernels import ops as kops
    from repro.kernels import ref as kref

    if dedup_cap is None:
        # a tight cap (the bench headline's 512 at pop=1024): the fixed-
        # shape unique table is interpreted in full, so cap/pop bounds
        # the dedup eval's share of the plain interpreter work
        dedup_cap = min(512, max(64, pop // 2))
    spec_t = TreeSpec(max_depth=max_depth, n_features=n_features, n_consts=8)
    spec_p = dataclasses.replace(spec_t, genome="postfix")
    fs = FitnessSpec(kernel)
    op_t, arg_t = generate_population(jax.random.PRNGKey(0), pop, spec_t)
    op_p, arg_p = heap_to_postfix(op_t, arg_t)
    op_d = jnp.tile(op_p[:8], (pop // 8, 1))
    arg_d = jnp.tile(arg_p[:8], (pop // 8, 1))
    X = jnp.zeros((n_features, rows), jnp.float32)
    y = jnp.zeros((rows,), jnp.float32)
    const = jnp.asarray(spec_t.const_table())
    lens = (jnp.asarray(op_p) != 0).sum(-1)
    active = int(lens.sum())          # total live primitives in the population
    max_len = int(lens.max())         # true bound of the postfix fori_loop
    useful = float(active) * rows     # one flop per (live node × data point)
    active_d = int((jnp.asarray(op_d) != 0).sum())
    useful_d = float(active_d) * rows
    uniq_n, saved_n = (int(v) for v in core_eval.dedup_stats(
        op_d, arg_d, spec_p, dedup_cap))

    def plain_dup(o, a, X, y):
        return kref.fitness_ref(o, a, X, y, const, spec_p, fs)

    def build_plan(o, a):
        return core_eval.build_dedup_plan(o, a, spec_p, dedup_cap)

    def dedup_engaged(plan, X, y):
        from repro.core.fitness import fitness_from_preds

        preds = core_eval.evaluate_unique_subtrees(
            plan, X, const, spec_p)[plan.root]
        return fitness_from_preds(preds, y, fs)

    plan = jax.jit(build_plan)(op_d, arg_d)
    lowered = {
        "tree": kops.fitness.lower(op_t, arg_t, X, y, const, tree_spec=spec_t,
                                   fit_spec=fs),
        "postfix": kops.fitness.lower(op_p, arg_p, X, y, const,
                                      tree_spec=spec_p, fit_spec=fs),
        "postfix-dup": jax.jit(plain_dup).lower(op_d, arg_d, X, y),
        "postfix-dedup": jax.jit(dedup_engaged).lower(plan, X, y),
        "dedup-plan": jax.jit(build_plan).lower(op_d, arg_d),
    }
    cells = []
    for tag, low in lowered.items():
        cost = analyze_hlo_text(low.compile().as_text(), unknown_trip=max_len)
        mf = (0.0 if tag == "dedup-plan"
              else useful_d if tag.startswith("postfix-d") else useful)
        cells.append({
            "genome": tag, "pop": pop, "rows": rows, "max_depth": max_depth,
            "n_nodes": int(op_p.shape[1]), "fitness_kernel": kernel,
            "max_program_len": max_len,
            "hlo_flops": cost["flops"], "hlo_bytes": cost["bytes"],
            "intensity_flops_per_byte": (cost["flops"] / cost["bytes"]
                                         if cost["bytes"] else 0.0),
            "model_flops": mf,
            "useful_ratio": (mf / cost["flops"]) if cost["flops"] else 0.0,
        })
        if tag == "postfix-dedup":
            cells[-1].update(dedup_cap=dedup_cap, unique_subtrees=uniq_n,
                             subtree_evals_saved=saved_n)
    by = {c["genome"]: c for c in cells}
    t, p = by["tree"], by["postfix"]
    dup, ded = by["postfix-dup"], by["postfix-dedup"]
    summary = {
        "postfix_over_tree_flops": (p["hlo_flops"] / t["hlo_flops"]
                                    if t["hlo_flops"] else 0.0),
        "postfix_over_tree_bytes": (p["hlo_bytes"] / t["hlo_bytes"]
                                    if t["hlo_bytes"] else 0.0),
        "dedup_over_plain_flops": (ded["hlo_flops"] / dup["hlo_flops"]
                                   if dup["hlo_flops"] else 0.0),
        "dedup_over_plain_bytes": (ded["hlo_bytes"] / dup["hlo_bytes"]
                                   if dup["hlo_bytes"] else 0.0),
    }
    if out_path:
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump({"cells": cells, **summary}, f, indent=1)
    return cells, summary


def fmt_gp_table(cells, summary) -> str:
    head = (f"{'genome':14s} {'pop':>6s} {'rows':>7s} {'GFLOPs':>9s} "
            f"{'GBytes':>9s} {'flops/B':>8s} {'useful':>7s}")
    lines = [head, "-" * len(head)]
    for c in cells:
        lines.append(
            f"{c['genome']:14s} {c['pop']:6d} {c['rows']:7d} "
            f"{c['hlo_flops']/1e9:9.3f} {c['hlo_bytes']/1e9:9.3f} "
            f"{c['intensity_flops_per_byte']:8.3f} {c['useful_ratio']:7.3f}")
    lines.append(f"postfix/tree  flops ×{summary['postfix_over_tree_flops']:.3f}"
                 f"  bytes ×{summary['postfix_over_tree_bytes']:.3f}")
    cap = next((c["dedup_cap"] for c in cells if "dedup_cap" in c), "?")
    lines.append(f"dedup/plain   flops ×{summary['dedup_over_plain_flops']:.3f}"
                 f"  bytes ×{summary['dedup_over_plain_bytes']:.3f}"
                 f"  (dup-heavy pop, cap={cap}, plan costed separately)")
    return "\n".join(lines)


def build_table(art_dir: str = "benchmarks/artifacts/dryrun",
                out_path: str = "benchmarks/artifacts/roofline.json"):
    rows = []
    for p in sorted(glob.glob(os.path.join(art_dir, "*_sp.json"))):
        rec = analyze_cell(p)
        if rec:
            rows.append(rec)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def fmt_table(rows) -> str:
    head = (f"{'arch':26s} {'shape':12s} {'dom':10s} {'t_comp':>9s} {'t_mem':>9s} "
            f"{'t_coll':>9s} {'useful':>7s} {'roofl%':>7s}")
    lines = [head, "-" * len(head)]
    for r in rows:
        if r.get("status") != "ok":
            lines.append(f"{r.get('arch',''):26s} {r.get('shape',''):12s} "
                         f"{r.get('status')}")
            continue
        lines.append(
            f"{r['arch']:26s} {str(r.get('shape','')):12s} {r['dominant']:10s} "
            f"{r['t_compute_s']:9.4f} {r['t_memory_s']:9.4f} "
            f"{r['t_collective_s']:9.4f} {r['useful_ratio']:7.3f} "
            f"{100*r['roofline_fraction']:7.2f}")
    return "\n".join(lines)


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "gp-eval":
        kv = dict(tok.split("=", 1) for tok in sys.argv[2:])
        cells, summary = gp_eval_cost(
            pop=int(kv.get("pop", 512)), rows=int(kv.get("rows", 16384)),
            max_depth=int(kv.get("max_depth", 5)),
            n_features=int(kv.get("n_features", 4)),
            kernel=kv.get("kernel", "r"),
            dedup_cap=(int(kv["dedup_cap"]) if "dedup_cap" in kv else None))
        print(fmt_gp_table(cells, summary))
    else:
        rows = build_table(*(sys.argv[1:] or []))
        print(fmt_table(rows))
