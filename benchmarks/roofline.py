"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

For every (arch × shape) cell compiled on the single-pod 16×16 mesh:

  compute term    = HLO_FLOPs_per_device / 197e12        (bf16 MXU peak)
  memory term     = HLO_bytes_per_device / 819e9         (HBM bandwidth)
  collective term = collective_bytes_per_device / 50e9   (ICI per link)

FLOPs/bytes/collective bytes come from benchmarks/hlo_cost.py (trip-count-
aware; XLA's cost_analysis undercounts every scanned loop). The dominant
term ≈ the step-time lower bound; MODEL_FLOPS/HLO_FLOPs shows how much of
the compiled compute is "useful" (remat, padding, dispatch waste).

CPU-backend caveats (documented per-cell where they bite):
  * bf16 dots are float-normalized to f32 on CPU — flops unaffected, but
    memory bytes of dot operands read ~2× larger than TPU-true. We report
    a bf16-corrected memory term alongside the raw one.
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.hlo_cost import analyze_file  # noqa: E402

PEAK_FLOPS = 197e12  # bf16 / chip (v5e)
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / ICI link
CHIPS = 256  # single pod

SHAPE_TOKENS = {"train_4k": (4096, 256), "prefill_32k": (32768, 32),
                "decode_32k": (1, 128), "long_500k": (1, 1)}

# active params (B) per arch — 6·N·D numerator (decode/prefill use 2·N·D)
ACTIVE_PARAMS = {
    "qwen1.5-32b": 32.5e9, "gemma-2b": 2.5e9, "mistral-large-123b": 122.6e9,
    "minitron-8b": 8.3e9, "granite-moe-3b-a800m": 1.0e9,
    "qwen3-moe-30b-a3b": 3.3e9, "whisper-medium": 0.76e9,
    "mamba2-370m": 0.37e9, "jamba-1.5-large-398b": 94e9,
    "llama-3.2-vision-90b": 88e9,
}


def model_flops(arch: str, shape: str) -> float:
    """Per-device useful FLOPs (6ND train / 2ND forward), GP cells
    handled separately."""
    seq, batch = SHAPE_TOKENS[shape]
    n = ACTIVE_PARAMS[arch]
    mult = 6.0 if shape == "train_4k" else 2.0
    return mult * n * seq * batch / CHIPS


def gp_model_flops(pop: int, rows: int, nodes: int = 63) -> float:
    """GP useful work: one primitive application per (tree-node × point)."""
    return pop * nodes * rows / CHIPS


def analyze_cell(json_path: str) -> dict | None:
    with open(json_path) as f:
        rec = json.load(f)
    if rec.get("status") != "ok":
        return rec
    hlo_path = json_path.replace(".json", ".hlo.txt")
    if os.path.exists(hlo_path):
        cost = analyze_file(hlo_path)
    else:
        cost = {"flops": rec["flops"], "bytes": rec["bytes_accessed"],
                "collective_bytes": sum(rec["collective_bytes"].values()),
                "collectives": rec["collective_bytes"]}
    arch, shape = rec["arch"], rec.get("shape", "")
    t_c = cost["flops"] / PEAK_FLOPS
    t_m = cost["bytes"] / HBM_BW
    t_coll = cost["collective_bytes"] / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    if arch in ACTIVE_PARAMS and shape in SHAPE_TOKENS:
        mf = model_flops(arch, shape)
    elif arch.startswith("karoo"):
        import re as _re
        mpop = _re.search(r"pop(\d+)", shape)
        mrows = _re.search(r"rows(\d+)", shape)
        mf = (gp_model_flops(int(mpop.group(1)), int(mrows.group(1)))
              if mpop and mrows else 0.0)
    else:
        mf = 0.0
    bound = max(terms.values())
    rec.update({
        "hlo_flops": cost["flops"], "hlo_bytes": cost["bytes"],
        "hlo_collective_bytes": cost["collective_bytes"],
        "hlo_collectives": cost.get("collectives", {}),
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": (mf / cost["flops"]) if cost["flops"] else 0.0,
        "roofline_fraction": (mf / PEAK_FLOPS) / bound if bound else 0.0,
    })
    return rec


def build_table(art_dir: str = "benchmarks/artifacts/dryrun",
                out_path: str = "benchmarks/artifacts/roofline.json"):
    rows = []
    for p in sorted(glob.glob(os.path.join(art_dir, "*_sp.json"))):
        rec = analyze_cell(p)
        if rec:
            rows.append(rec)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def fmt_table(rows) -> str:
    head = (f"{'arch':26s} {'shape':12s} {'dom':10s} {'t_comp':>9s} {'t_mem':>9s} "
            f"{'t_coll':>9s} {'useful':>7s} {'roofl%':>7s}")
    lines = [head, "-" * len(head)]
    for r in rows:
        if r.get("status") != "ok":
            lines.append(f"{r.get('arch',''):26s} {r.get('shape',''):12s} "
                         f"{r.get('status')}")
            continue
        lines.append(
            f"{r['arch']:26s} {str(r.get('shape','')):12s} {r['dominant']:10s} "
            f"{r['t_compute_s']:9.4f} {r['t_memory_s']:9.4f} "
            f"{r['t_collective_s']:9.4f} {r['useful_ratio']:7.3f} "
            f"{100*r['roofline_fraction']:7.2f}")
    return "\n".join(lines)


if __name__ == "__main__":
    rows = build_table(*(sys.argv[1:] or []))
    print(fmt_table(rows))
